"""Shared harness for the paper-replication benchmarks.

Every benchmark drives a real VirtualClusterFramework (no mocks besides the
paper's own virtual-kubelet instant-ready provider) and measures end-to-end
WorkUnit creation latency exactly as §IV defines it: tenant-side creation
timestamp -> tenant-side Ready-condition timestamp, including all queuing
delays and synchronization overheads.
"""
from __future__ import annotations

import statistics
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core import VirtualClusterFramework, Namespace, WorkUnit


@dataclass
class LatencyStats:
    latencies: List[float] = field(default_factory=list)

    @property
    def n(self) -> int:
        return len(self.latencies)

    def pct(self, p: float) -> float:
        if not self.latencies:
            return 0.0
        s = sorted(self.latencies)
        return s[min(len(s) - 1, int(len(s) * p))]

    @property
    def mean(self) -> float:
        return statistics.mean(self.latencies) if self.latencies else 0.0

    def histogram(self, bucket: float = 1.0, max_b: float = 20.0
                  ) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for lat in self.latencies:
            lo = min(int(lat / bucket), int(max_b / bucket)) * bucket
            key = f"[{lo:g},{lo + bucket:g})"
            out[key] = out.get(key, 0) + 1
        return dict(sorted(out.items(), key=lambda kv: float(
            kv[0][1:].split(",")[0])))


def make_framework(num_nodes: int = 100, *, downward_workers: int = 20,
                   upward_workers: int = 100, fair_queuing: bool = True,
                   scan_interval: float = 0.0,
                   parallel_scorers: int = 0,
                   syncer_shards: int = 1,
                   downward_batch: int = 1,
                   metering: bool = False,
                   audit: bool = False) -> VirtualClusterFramework:
    return VirtualClusterFramework(
        num_nodes=num_nodes, downward_workers=downward_workers,
        upward_workers=upward_workers, fair_queuing=fair_queuing,
        scan_interval=scan_interval, router_scan_interval=0.0,
        heartbeat_interval=3600.0,   # heartbeats off the hot path
        parallel_scorers=parallel_scorers,
        syncer_shards=syncer_shards, downward_batch=downward_batch,
        metering=metering, audit=audit)


def syncer_metrics_summary(fw: VirtualClusterFramework) -> Dict[str, float]:
    """Headline controller-runtime metrics for benchmark records."""
    snap = fw.metrics.snapshot()
    out: Dict[str, float] = {}
    down_total = down_retries = 0.0
    lat_sum = lat_count = 0.0
    for key, val in snap["counters"].items():
        if key.startswith("reconcile_total{controller=syncer-dws"):
            down_total += val
        if key.startswith("reconcile_retries{controller=syncer-dws"):
            down_retries += val
    for key, s in snap["summaries"].items():
        if key.startswith("reconcile_seconds{controller=syncer-dws"):
            lat_sum += s["sum"]
            lat_count += s["count"]
    out["downward_reconciles"] = down_total
    out["downward_retries"] = down_retries
    out["downward_reconcile_mean_ms"] = (
        lat_sum / lat_count * 1e3 if lat_count else 0.0)
    out["upward_reconciles"] = sum(
        val for key, val in snap["counters"].items()
        if key.startswith("reconcile_total{controller=syncer-uws"))
    out["scheduler_reconciles"] = snap["counters"].get(
        "reconcile_total{controller=scheduler}", 0.0)
    return out


def submit_burst(fw: VirtualClusterFramework, planes, units_per_tenant: int,
                 chips: int = 0) -> float:
    """All tenants submit their units concurrently; returns submit wall time."""
    t0 = time.monotonic()

    def submit(plane):
        ns = Namespace()
        ns.metadata.name = "bench"
        try:
            plane.api.create(ns)
        except Exception:
            pass
        for j in range(units_per_tenant):
            unit = fw.make_unit(f"u{j:05d}", "bench", chips=chips)
            plane.api.create(unit)

    threads = [threading.Thread(target=submit, args=(p,)) for p in planes]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.monotonic() - t0


def wait_and_collect(fw: VirtualClusterFramework, planes,
                     units_per_tenant: int, timeout: float = 600.0
                     ) -> Tuple[LatencyStats, float]:
    """Wait until all Ready; return (per-unit latencies, total wall time)."""
    t0 = time.monotonic()
    for plane in planes:
        fw.wait_all_ready(plane, "bench", units_per_tenant, timeout=timeout)
    total = time.monotonic() - t0
    stats = LatencyStats()
    for plane in planes:
        for u in plane.api.list("WorkUnit", "bench"):
            cond = u.status.condition("Ready")
            if cond and cond.status == "True":
                stats.latencies.append(
                    cond.last_transition_time - u.metadata.creation_timestamp)
    return stats, total


def baseline_burst(num_nodes: int, tenants: int, units_per_tenant: int,
                   timeout: float = 600.0) -> Tuple[LatencyStats, float]:
    """Paper baseline: the load generator sends all requests straight to the
    super cluster with one thread per tenant."""
    fw = make_framework(num_nodes)
    with fw:
        t0 = time.monotonic()

        def submit(i):
            ns = Namespace()
            ns.metadata.name = f"direct-{i}"
            fw.super_api.create(ns)
            for j in range(units_per_tenant):
                unit = fw.make_unit(f"u{j:05d}", f"direct-{i}", chips=0)
                fw.super_api.create(unit)

        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(tenants)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        deadline = time.monotonic() + timeout
        want = tenants * units_per_tenant
        while time.monotonic() < deadline:
            ready = sum(1 for u in fw.super_api.list("WorkUnit")
                        if u.status.phase == "Ready")
            if ready >= want:
                break
            time.sleep(0.05)
        total = time.monotonic() - t0
        stats = LatencyStats()
        for u in fw.super_api.list("WorkUnit"):
            cond = u.status.condition("Ready")
            if cond and cond.status == "True":
                stats.latencies.append(
                    cond.last_transition_time - u.metadata.creation_timestamp)
        return stats, total


def vc_burst(tenants: int, units_per_tenant: int, *, num_nodes: int = 100,
             downward_workers: int = 20, upward_workers: int = 100,
             fair_queuing: bool = True, timeout: float = 600.0,
             syncer_shards: int = 1, downward_batch: int = 1
             ) -> Tuple[LatencyStats, float, VirtualClusterFramework]:
    """Full VirtualCluster path; caller must iterate results before stop()."""
    fw = make_framework(num_nodes, downward_workers=downward_workers,
                        upward_workers=upward_workers,
                        fair_queuing=fair_queuing,
                        syncer_shards=syncer_shards,
                        downward_batch=downward_batch)
    fw.start()
    try:
        planes = [fw.add_tenant(f"t{i:03d}") for i in range(tenants)]
        submit_burst(fw, planes, units_per_tenant)
        stats, total = wait_and_collect(fw, planes, units_per_tenant,
                                        timeout=timeout)
        return stats, total, fw
    finally:
        fw.stop()
