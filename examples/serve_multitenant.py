"""Multi-tenant serving: two tenants share one GenerationEngine fleet.

Each tenant registers a Service (router injects its routing rules into the
serving WorkUnits' guest tables before they start — the paper's enhanced-
kubeproxy path), then streams generation requests through the continuous
batcher. Fair queuing keeps the bursty tenant from starving the steady one.

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import Service, VirtualClusterFramework
from repro.models import init_params
from repro.serving import ContinuousBatcher, GenerationEngine


def main():
    cfg = reduced(get_config("qwen2-7b"), n_layers=2, d_model=64, vocab=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = GenerationEngine(cfg, params, slots=4, max_len=64)
    batcher = ContinuousBatcher(engine)

    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=3600)
    with fw:
        tenants = {name: fw.add_tenant(name) for name in ("bursty", "steady")}
        # each tenant publishes a model endpoint service
        for name, plane in tenants.items():
            svc = Service()
            svc.metadata.name = f"{cfg.name}-endpoint"
            svc.metadata.namespace = "default"
            svc.virtual_ip = f"10.96.0.{len(name)}"
            svc.endpoints = ["engine-0"]
            fw.submit(plane, fw.make_unit("server", "default", chips=1,
                                          init_gate=True))
            plane.api.create(svc)
            fw.wait_ready(plane, "default", "server", timeout=30)
            u = plane.api.get("WorkUnit", "default", "server")
            print(f"[{name}] serving unit ready on vNode {u.status.node} "
                  f"(routing rules gated before start)")

        rng = np.random.default_rng(0)
        uids = {}
        t0 = time.monotonic()
        # bursty tenant: 12 requests at once; steady: 4
        for i in range(12):
            uids[batcher.submit(rng.integers(0, cfg.vocab, 12),
                                max_new_tokens=8)] = "bursty"
        for i in range(4):
            uids[batcher.submit(rng.integers(0, cfg.vocab, 12),
                                max_new_tokens=8)] = "steady"
        batcher.run_until_drained()
        wall = time.monotonic() - t0
        by_tenant = {}
        for uid, req in batcher.completed.items():
            by_tenant.setdefault(uids[uid], []).append(
                req.finished_at - req.submitted_at)
        toks = sum(len(r.tokens) for r in batcher.completed.values())
        print(f"served {len(batcher.completed)} requests / {toks} tokens "
              f"in {wall:.2f}s ({toks/wall:.0f} tok/s)")
        for name, lats in sorted(by_tenant.items()):
            print(f"  {name:7s}: {len(lats)} reqs, "
                  f"mean latency {sum(lats)/len(lats):.2f}s")
    print("done")


if __name__ == "__main__":
    main()
