"""Per-kernel correctness: Pallas (interpret mode) and XLA paths vs the
pure-jnp oracles, swept over shapes/dtypes; gradients vs autodiff-through-ref.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import _mha_xla, decode_mha
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.mamba_scan.ops import _mamba_xla, mamba_decode_step
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rwkv6_scan.ops import _rwkv6_xla, rwkv6_decode_step
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


ATTN_CASES = [
    # B, S, T, H, KV, D, causal, window, softcap
    (2, 128, 128, 4, 2, 64, True, 0, 0.0),
    (1, 100, 100, 4, 4, 32, True, 48, 50.0),     # ragged + window + softcap
    (2, 64, 256, 8, 2, 64, True, 0, 0.0),        # cross-size (q_offset)
    (1, 64, 64, 2, 1, 128, False, 0, 0.0),       # bidirectional (encoder)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_pallas_vs_ref(case, dtype):
    B, S, T, H, KV, D, causal, window, softcap = case
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, S, H, D), dtype)
    k = rand(ks[1], (B, T, KV, D), dtype)
    v = rand(ks[2], (B, T, KV, D), dtype)
    qoff = T - S if causal else 0
    ref = mha_ref(q, k, v, causal=causal, window=window, softcap=softcap,
                  q_offset=qoff)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, q_offset=qoff, block_q=32,
                          block_k=32, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_xla_vs_ref(case):
    B, S, T, H, KV, D, causal, window, softcap = case
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, T, KV, D), jnp.float32)
    v = rand(ks[2], (B, T, KV, D), jnp.float32)
    qoff = T - S if causal else 0
    ref = mha_ref(q, k, v, causal=causal, window=window, softcap=softcap,
                  q_offset=qoff)
    out = _mha_xla(q, k, v, causal=causal, window=window, softcap=softcap,
                   scale=None, q_offset=qoff, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", ATTN_CASES[:3])
def test_flash_attention_grads_vs_ref(case):
    B, S, T, H, KV, D, causal, window, softcap = case
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, T, KV, D), jnp.float32)
    v = rand(ks[2], (B, T, KV, D), jnp.float32)
    dout = rand(ks[3], (B, S, H, D), jnp.float32)
    qoff = T - S if causal else 0

    def loss_x(q, k, v):
        return (_mha_xla(q, k, v, causal=causal, window=window,
                         softcap=softcap, scale=None, q_offset=qoff,
                         q_chunk=32, kv_chunk=32) * dout).sum()

    def loss_r(q, k, v):
        return (mha_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap, q_offset=qoff) * dout).sum()

    gx = jax.grad(loss_x, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, (0, 1, 2))(q, k, v)
    for a, b in zip(gx, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


DECODE_CASES = [
    (2, 256, 8, 2, 64, 0, 0.0),
    (3, 200, 4, 4, 32, 64, 30.0),
    (2, 512, 16, 8, 128, 0, 0.0),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_pallas_vs_ref(case, dtype):
    B, L, H, KV, D, window, softcap = case
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (B, 1, H, D), dtype)
    kc = rand(ks[1], (B, L, KV, D), dtype)
    vc = rand(ks[2], (B, L, KV, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), L // 2, L + 1)
    ref = flash_decode_ref(q, kc, vc, lengths, window=window, softcap=softcap)
    out = flash_decode_pallas(q, kc, vc, lengths, window=window,
                              softcap=softcap, block_k=64, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_mha_xla_vs_ref(case):
    B, L, H, KV, D, window, softcap = case
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (B, 1, H, D), jnp.float32)
    kc = rand(ks[1], (B, L, KV, D), jnp.float32)
    vc = rand(ks[2], (B, L, KV, D), jnp.float32)
    lengths = jax.random.randint(ks[3], (B,), L // 2, L + 1)
    ref = flash_decode_ref(q, kc, vc, lengths, window=window, softcap=softcap)
    out = decode_mha(q, kc, vc, lengths, window=window, softcap=softcap,
                     kv_chunk=64, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


RWKV_CASES = [(2, 80, 2, 16), (1, 33, 4, 8), (2, 16, 1, 32)]


@pytest.mark.parametrize("shape", RWKV_CASES)
def test_rwkv6_chunked_vs_ref(shape):
    B, S, H, D = shape
    ks = jax.random.split(KEY, 5)
    r = rand(ks[0], (B, S, H, D), jnp.float32) * 0.5
    k = rand(ks[1], (B, S, H, D), jnp.float32) * 0.5
    v = rand(ks[2], (B, S, H, D), jnp.float32) * 0.5
    w = jnp.exp(-jnp.exp(rand(ks[3], (B, S, H, D), jnp.float32) * 0.5))
    u = rand(ks[4], (H, D), jnp.float32) * 0.1
    o1, s1 = _rwkv6_xla(r, k, v, w, u, None, chunk=16)
    o2, s2 = rwkv6_scan_ref(r, k, v, w, u, None)
    # chunked form reassociates exp-cumulations: fp32 roundoff ~1e-3 abs on
    # O(5) outputs (the serial oracle and the chunked path agree to ~3e-4 rel)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=5e-3, rtol=5e-3)


def test_rwkv6_decode_matches_scan_tail():
    B, S, H, D = 2, 17, 2, 16
    ks = jax.random.split(KEY, 5)
    r = rand(ks[0], (B, S, H, D), jnp.float32) * 0.5
    k = rand(ks[1], (B, S, H, D), jnp.float32) * 0.5
    v = rand(ks[2], (B, S, H, D), jnp.float32) * 0.5
    w = jnp.exp(-jnp.exp(rand(ks[3], (B, S, H, D), jnp.float32) * 0.5))
    u = rand(ks[4], (H, D), jnp.float32) * 0.1
    o_full, s_full = rwkv6_scan_ref(r, k, v, w, u, None)
    _, s_prefix = rwkv6_scan_ref(r[:, :-1], k[:, :-1], v[:, :-1], w[:, :-1],
                                 u, None)
    o_step, s_step = rwkv6_decode_step(r[:, -1], k[:, -1], v[:, -1], w[:, -1],
                                       u, s_prefix)
    np.testing.assert_allclose(np.asarray(o_step), np.asarray(o_full[:, -1]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_step), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


MAMBA_CASES = [(2, 64, 8, 4), (1, 33, 16, 2), (2, 16, 4, 8)]


@pytest.mark.parametrize("shape", MAMBA_CASES)
def test_mamba_chunked_vs_ref(shape):
    Bt, S, DI, N = shape
    ks = jax.random.split(KEY, 6)
    x = rand(ks[0], (Bt, S, DI), jnp.float32) * 0.5
    dt = jax.nn.softplus(rand(ks[1], (Bt, S, DI), jnp.float32))
    A = -jnp.exp(rand(ks[2], (DI, N), jnp.float32) * 0.3)
    B = rand(ks[3], (Bt, S, N), jnp.float32) * 0.5
    C = rand(ks[4], (Bt, S, N), jnp.float32) * 0.5
    D = jnp.ones((DI,))
    y1, h1 = _mamba_xla(x, dt, A, B, C, D, None, chunk=16)
    y2, h2 = mamba_scan_ref(x, dt, A, B, C, D, None)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=5e-5, rtol=5e-4)


def test_mamba_decode_matches_scan_tail():
    Bt, S, DI, N = 2, 9, 8, 4
    ks = jax.random.split(KEY, 6)
    x = rand(ks[0], (Bt, S, DI), jnp.float32) * 0.5
    dt = jax.nn.softplus(rand(ks[1], (Bt, S, DI), jnp.float32))
    A = -jnp.exp(rand(ks[2], (DI, N), jnp.float32) * 0.3)
    B = rand(ks[3], (Bt, S, N), jnp.float32) * 0.5
    C = rand(ks[4], (Bt, S, N), jnp.float32) * 0.5
    D = jnp.ones((DI,))
    y_full, h_full = mamba_scan_ref(x, dt, A, B, C, D, None)
    _, h_prefix = mamba_scan_ref(x[:, :-1], dt[:, :-1], A, B[:, :-1],
                                 C[:, :-1], D, None)
    y_step, h_step = mamba_decode_step(x[:, -1], dt[:, -1], A, B[:, -1],
                                       C[:, -1], D, h_prefix)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, -1]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_step), np.asarray(h_full),
                               atol=1e-4, rtol=1e-4)
