"""ShapeDtypeStruct stand-ins for every model input/state (no allocation).

The dry-run lowers train/serve steps against these; nothing here ever
touches a device buffer.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models import init_cache, init_params
from ..models.config import ModelConfig, ShapeConfig
from ..training.optimizer import init_opt_state

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch inputs for one step of the given kind."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": SDS((B, S), jnp.int32),
            "mask": SDS((B, S), jnp.float32),
        }
        if cfg.frontend == "vit_stub":
            batch["patches"] = SDS((B, cfg.frontend_tokens, cfg.frontend_dim),
                                   jnp.float32)
        elif cfg.frontend == "speech_stub":
            batch["frames"] = SDS((B, S, cfg.frontend_dim), jnp.float32)
        return batch
    if shape.kind == "prefill":
        out = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.frontend == "vit_stub":
            out["patches"] = SDS((B, cfg.frontend_tokens, cfg.frontend_dim),
                                 jnp.float32)
        elif cfg.frontend == "speech_stub":
            out["frames"] = SDS((B, S, cfg.frontend_dim), jnp.float32)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": SDS((B, 1), jnp.int32),
            "lengths": SDS((B,), jnp.int32)}


def param_specs(cfg: ModelConfig, dtype=None) -> Any:
    """ShapeDtypeStructs of the parameter pytree (optionally re-dtyped —
    serving uses bf16 params, training fp32 masters)."""
    tree = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
    if dtype is not None:
        tree = jax.tree.map(
            lambda s: SDS(s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
                          else s.dtype), tree)
    return tree


def opt_specs(params_tree: Any) -> Any:
    return jax.eval_shape(init_opt_state, params_tree)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Any:
    B = shape.global_batch
    L = shape.seq_len
    enc_len = shape.seq_len if cfg.is_encdec else 0
    return jax.eval_shape(
        functools.partial(init_cache, cfg, B, max_len=L, enc_len=enc_len,
                          dtype=dtype))
