"""Sharded checkpointing with async write and atomic commit.

Layout: <dir>/step_<N>/{manifest.json, <flat-key>.npy ...}. A checkpoint is
valid iff manifest.json exists (written last — atomic-rename commit), so a
crash mid-write never yields a readable-but-corrupt checkpoint. ``restore``
returns the pytree re-sharded to the caller's shardings (device_put), which
is how node-failure restarts and elastic re-scaling re-materialize state on
a different mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self.save_count = 0

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, *, block: bool = False) -> None:
        """Snapshot to host memory synchronously; write to disk async."""
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self.wait()  # one outstanding write at a time
        if self.async_write and not block:
            self._pending = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._pending.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "keys": {}}
        for key, arr in flat.items():
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["keys"][key] = {"file": fname, "shape": list(arr.shape),
                                     "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # commit
        self.save_count += 1
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, int]:
        """Restore into the structure of ``tree_like``; re-shard if given."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(tree_like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out_flat = {}
        for key in flat_like:
            meta = manifest["keys"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint {d} missing key {key}")
            arr = np.load(os.path.join(d, meta["file"]))
            if key in flat_shard:
                out_flat[key] = jax.device_put(arr, flat_shard[key])
            else:
                out_flat[key] = jax.numpy.asarray(arr)
        # rebuild the tree
        leaves_with_path = jax.tree_util.tree_flatten_with_path(tree_like)
        treedef = leaves_with_path[1]
        ordered = []
        for path, _ in leaves_with_path[0]:
            key = "/".join(_path_str(p) for p in path)
            ordered.append(out_flat[key])
        return jax.tree_util.tree_unflatten(treedef, ordered), step
