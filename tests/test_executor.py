"""Shared cooperative executor: timer-wheel ordering, starvation freedom
across controllers on one pool, informer handover under resize_shards with
events in flight, the O(pool) thread bound at 64 tenants, DelayingQueue
shutdown semantics, and the metrics HTTP endpoint."""
import json
import threading
import time
import urllib.request

import pytest

from repro.core import (APIServer, Controller, ControllerManager,
                        CooperativeExecutor, Namespace, Syncer, Task,
                        TenantControlPlane, VirtualClusterFramework, WorkUnit)
from repro.core.workqueue import DelayingQueue, WorkQueue


def wait_for(cond, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.005)
    return False


@pytest.fixture
def ex():
    ex = CooperativeExecutor(pool_size=4, name="test")
    ex.start()
    yield ex
    ex.shutdown()


# ------------------------------------------------------------------ executor

def test_executor_thread_count_is_pool_size_not_task_count(ex):
    base = threading.active_count()
    waited = [ex.spawn(lambda: Task.WAIT, name=f"idle-{i}")
              for i in range(200)]
    assert wait_for(lambda: ex.ready_backlog() == 0)
    assert ex.task_count() >= 200
    assert threading.active_count() == base          # zero threads per task
    for t in waited:
        t.cancel()
    assert wait_for(lambda: ex.task_count() == 0)


def test_timer_wheel_fires_in_deadline_order(ex):
    fired = []
    lock = threading.Lock()

    def mark(tag):
        def fn():
            with lock:
                fired.append(tag)
        return fn

    # armed out of order; must fire in deadline order off one shared wheel
    ex.call_later(0.15, mark("c"))
    ex.call_later(0.05, mark("a"))
    ex.call_later(0.10, mark("b"))
    assert ex.timer_depth() == 3
    assert wait_for(lambda: len(fired) == 3)
    assert fired == ["a", "b", "c"]
    assert ex.timer_depth() == 0


def test_task_wake_during_run_requeues_once_more(ex):
    runs = []
    gate = threading.Event()

    def fn():
        runs.append(time.monotonic())
        gate.wait(1.0)
        return Task.WAIT

    t = ex.spawn(fn, name="rewake")
    assert wait_for(lambda: len(runs) == 1)
    t.wake()            # lands while RUNNING -> pending -> one more quantum
    gate.set()
    assert wait_for(lambda: len(runs) == 2)
    time.sleep(0.05)
    assert len(runs) == 2


def test_task_errors_do_not_kill_the_pool(ex):
    def boom():
        raise RuntimeError("induced")

    t = ex.spawn(boom, name="boom")
    assert wait_for(lambda: ex.task_errors >= 1)
    assert t.alive                  # broken task idles; pool unharmed
    ok = []
    ex.spawn(lambda: ok.append(1) or Task.DONE, name="after")
    assert wait_for(lambda: ok == [1])


# ------------------------------------------------------------- live resize

def test_resize_grow_under_load_adds_threads_and_drains():
    """Growing mid-burst: new threads join the drain and every queued
    quantum still runs exactly once."""
    ex = CooperativeExecutor(pool_size=1, name="grow")
    ex.start()
    try:
        done = []
        lock = threading.Lock()

        def work(i):
            def fn():
                time.sleep(0.002)
                with lock:
                    done.append(i)
                return Task.DONE
            return fn

        for i in range(100):
            ex.spawn(work(i), name=f"w{i}")
        assert ex.resize(6) == 1
        assert ex.pool_size == 6
        assert wait_for(lambda: ex.thread_count() == 6)
        assert wait_for(lambda: len(done) == 100, timeout=10.0)
        assert sorted(done) == list(range(100))
    finally:
        ex.shutdown()


def test_resize_shrink_with_parked_tasks_loses_no_wakes():
    """Shrink while tasks are parked on wakers: every later wake must still
    run a quantum — retiring threads hand stranded wakes to survivors."""
    ex = CooperativeExecutor(pool_size=6, name="shrink")
    ex.start()
    try:
        runs = [0] * 40
        lock = threading.Lock()

        def parked(i):
            def fn():
                with lock:
                    runs[i] += 1
                return Task.WAIT
            return fn

        tasks = [ex.spawn(parked(i), name=f"p{i}") for i in range(40)]
        assert wait_for(lambda: sum(runs) == 40)     # first quantum each
        ex.resize(1)
        # retire is lazy (quantum-boundary poison): surplus threads exit on
        # their next wake; the burst below both exercises the wakes and
        # flushes the poison
        for t in tasks:
            t.wake()
        assert wait_for(lambda: sum(runs) == 80, timeout=10.0)
        assert all(r == 2 for r in runs)
        assert wait_for(lambda: ex.thread_count() == 1)
        # the survivor still serves fresh wakes
        for t in tasks:
            t.wake()
        assert wait_for(lambda: sum(runs) == 120, timeout=10.0)
    finally:
        ex.shutdown()


def test_resize_to_one_from_pool_thread_no_self_deadlock():
    """The autoscaler tick runs ON the pool: a task shrinking the pool to 1
    (possibly retiring its own thread) must not deadlock the executor."""
    ex = CooperativeExecutor(pool_size=4, name="self-shrink")
    ex.start()
    try:
        shrunk = threading.Event()

        def shrink():
            ex.resize(1)
            shrunk.set()
            return Task.DONE

        ex.spawn(shrink, name="shrinker")
        assert shrunk.wait(5.0)
        assert wait_for(lambda: ex.thread_count() == 1)
        after = []
        ex.spawn(lambda: after.append(1) or Task.DONE, name="after")
        assert wait_for(lambda: after == [1])        # survivor still runs
        # and grow again, from the single remaining thread
        regrown = threading.Event()

        def grow():
            ex.resize(3)
            regrown.set()
            return Task.DONE

        ex.spawn(grow, name="grower")
        assert regrown.wait(5.0)
        assert wait_for(lambda: ex.thread_count() == 3)
    finally:
        ex.shutdown()


def test_shutdown_with_pending_retire_keeps_thread_count_sane():
    """Threads exiting via the stop flag never consume poison quanta;
    shutdown must clear them so thread_count()/executor_threads can't go
    negative between shutdown and the next start."""
    ex = CooperativeExecutor(pool_size=6, name="pending-retire")
    ex.start()
    ex.resize(2)            # 4 poison quanta possibly still pending...
    ex.shutdown()           # ...when the stop flag empties the pool
    assert ex.thread_count() == 0
    ex.start()              # restart honors the resized pool_size
    try:
        assert wait_for(lambda: ex.thread_count() == 2)
    finally:
        ex.shutdown()


def test_resize_when_stopped_applies_at_next_start():
    ex = CooperativeExecutor(pool_size=2, name="stopped")
    assert ex.resize(5) == 2          # records the size, spawns nothing
    assert ex.thread_count() == 0
    ex.start()
    try:
        assert wait_for(lambda: ex.thread_count() == 5)
    finally:
        ex.shutdown()


class Recorder(Controller):
    def __init__(self, name, queue=None, delay=0.0, **kw):
        super().__init__(name, queue=queue or WorkQueue(name), **kw)
        self.seen = []
        self.delay = delay
        self._seen_lock = threading.Lock()

    def reconcile(self, key):
        if self.delay:
            time.sleep(self.delay)
        with self._seen_lock:
            self.seen.append(key)


def test_starvation_freedom_two_controllers_one_pool():
    """A controller flooding the pool must not starve a light controller:
    FIFO ready-deque dispatch with bounded quanta interleaves them."""
    ex = CooperativeExecutor(pool_size=2, name="tiny")
    heavy = Recorder("heavy", workers=2, delay=0.002)
    light = Recorder("light", workers=1)
    m = ControllerManager(executor=ex)
    m.add(heavy, light)
    m.start()
    try:
        for i in range(300):
            heavy.queue.add(f"h{i}")
        for i in range(5):
            light.queue.add(f"l{i}")
        assert wait_for(lambda: len(light.seen) == 5, timeout=5.0)
        # the light controller finished while the flood was still draining
        assert len(heavy.seen) < 300
        assert wait_for(lambda: len(heavy.seen) == 300, timeout=30.0)
    finally:
        m.stop()


def test_controller_restart_and_health_on_executor(ex):
    c = Recorder("restartable", workers=2)
    c.executor = ex
    c.start()
    assert c.healthy()
    c.queue.add("a")
    assert wait_for(lambda: c.seen == ["a"])
    c.stop()
    assert not c.healthy()
    c.start()
    c.queue.add("b")
    assert wait_for(lambda: c.seen == ["a", "b"])
    c.stop()


# ------------------------------------------------------- delaying queue fix

def test_delaying_queue_shutdown_cancels_pending_timers():
    q = DelayingQueue("dq")
    q.add_after("k", 0.05)
    q.shutdown()
    q.reopen()               # drained queue reopened (controller restart)
    time.sleep(0.12)
    assert len(q) == 0       # the cancelled timer must not resurrect "k"


def test_delaying_queue_add_after_post_shutdown_is_noop():
    q = DelayingQueue("dq2")
    q.shutdown()
    q.add_after("k", 0.01)   # no-op: no timer is even created
    q.reopen()
    time.sleep(0.05)
    assert len(q) == 0


def test_delaying_queue_on_executor_timer_wheel(ex):
    q = DelayingQueue("dq3")
    q.use_executor(ex)
    base = threading.active_count()
    q.add_after("k", 0.03)
    assert threading.active_count() == base   # no threading.Timer thread
    assert ex.timer_depth() >= 1
    assert wait_for(lambda: len(q) == 1)
    assert q.get(timeout=0) == "k"
    # shutdown cancels wheel entries too
    q.add_after("k2", 0.03)
    q.shutdown()
    q.reopen()
    time.sleep(0.08)
    assert len(q) == 0


# ------------------------------------------------ syncer on the shared pool

def _mk_unit(name, ns="default"):
    u = WorkUnit()
    u.metadata.name = name
    u.metadata.namespace = ns
    return u


def _syncer_rig(tenants, ex, shards=1, batch=1):
    super_api = APIServer("super")
    syncer = Syncer(super_api, downward_workers=4, upward_workers=2,
                    scan_interval=0.0, shards=shards, downward_batch=batch,
                    executor=ex)
    planes = [TenantControlPlane(f"t{i:03d}") for i in range(tenants)]
    for i, p in enumerate(planes):
        syncer.register_tenant(p, f"uid-{i:03d}")
    syncer.start()
    return super_api, syncer, planes


def test_thread_count_bounded_with_64_tenants():
    """The acceptance bound: 64 tenants x 5 informers each would be 300+
    threads in legacy mode; on the executor, OS thread count stays within
    the LIVE pool size + 8 — the bound tracks the dynamic pool through
    resizes in both directions, not the construction-time constant."""
    pool = 8
    base = threading.active_count()
    ex = CooperativeExecutor(pool_size=pool, name="dense")
    super_api, syncer, planes = _syncer_rig(64, ex)
    try:
        assert len(syncer.tenants) == 64
        assert ex.task_count() > 300          # informer pumps + workers
        assert threading.active_count() <= ex.pool_size + 8
        assert threading.active_count() - base <= ex.pool_size + 2
        # and the control plane actually works at that density
        for p in planes[:8]:
            ns = Namespace()
            ns.metadata.name = "default"
            p.api.create(ns)
            p.api.create(_mk_unit("u0"))
        assert wait_for(
            lambda: super_api.store.count("WorkUnit") >= 8, timeout=15.0)
        # the bound follows the pool through an autoscaler-style resize:
        # grow to 16 and back down to 4, still O(pool), never O(tenants)
        ex.resize(16)
        assert wait_for(lambda: ex.thread_count() == 16)
        assert threading.active_count() - base <= ex.pool_size + 2
        ex.resize(4)
        assert wait_for(
            lambda: threading.active_count() - base <= ex.pool_size + 2,
            timeout=15.0)
        assert ex.pool_size == 4
    finally:
        syncer.stop()
        ex.shutdown()
        super_api.close()
    assert wait_for(lambda: threading.active_count() <= base)


def test_resize_shards_handover_with_events_in_flight():
    """Live informer handover on the executor: grow the shard fleet while
    tenants are bursting; nothing is lost and no reflector restarts."""
    ex = CooperativeExecutor(pool_size=4, name="resize")
    super_api, syncer, planes = _syncer_rig(8, ex, shards=1, batch=4)
    try:
        for p in planes:
            ns = Namespace()
            ns.metadata.name = "default"
            p.api.create(ns)
        relists_before = {
            t: {k: inf.relist_count for k, inf in reg.informers.items()}
            for t, reg in syncer.tenants.items()}
        stop_burst = threading.Event()

        def burst(plane, idx):
            i = 0
            while not stop_burst.is_set():
                plane.api.create(_mk_unit(f"u{idx}-{i:04d}"))
                i += 1
                time.sleep(0.001)

        threads = [threading.Thread(target=burst, args=(p, i), daemon=True)
                   for i, p in enumerate(planes)]
        for t in threads:
            t.start()
        time.sleep(0.05)
        moved = syncer.resize_shards(3)
        time.sleep(0.05)
        stop_burst.set()
        for t in threads:
            t.join()
        assert moved                           # some tenants changed shard
        total = sum(p.api.store.count("WorkUnit") for p in planes)
        assert wait_for(
            lambda: super_api.store.count("WorkUnit") >= total, timeout=30.0)
        # handed-over informers kept their reflector tasks: no relists
        for t, reg in syncer.tenants.items():
            for k, inf in reg.informers.items():
                assert inf.relist_count == relists_before[t][k]
                assert inf.alive
    finally:
        syncer.stop()
        ex.shutdown()
        super_api.close()


# ----------------------------------------------------- metrics HTTP export

def test_serve_metrics_http_endpoint():
    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=0.5)
    with fw:
        port = fw.serve_metrics(port=0)
        snap = json.load(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5))
        assert set(snap) == {"counters", "summaries", "gauges", "histograms"}
        assert snap["gauges"]["executor_pool_size"] == 8.0
        assert "executor_ready_backlog" in snap["gauges"]
        assert "executor_timer_depth" in snap["gauges"]
        health = json.load(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/healthz", timeout=5))
        assert health["controllers"] and all(health["controllers"].values())
        assert health["autoscaler"] is None   # autoscale off by default
        assert health["slo"] == {}            # nothing observed yet
        traces = json.load(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/traces", timeout=5))
        assert traces == {"enabled": False, "stats": {}, "spans": []}
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope", timeout=5)


def test_framework_legacy_thread_mode_still_works():
    """The blocking-thread fallback stays alive (bisectable diff). Small
    worker budget: the default 120+ threads thrash small CI machines."""
    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=0.5, executor_mode=False,
                                 downward_workers=4, upward_workers=4)
    assert fw.executor is None
    with fw:
        plane = fw.add_tenant("legacy")
        fw.submit(plane, fw.make_unit("job", chips=1))
        u = fw.wait_ready(plane, "default", "job", timeout=60)
        assert u.status.phase == "Ready"
