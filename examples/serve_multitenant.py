"""Multi-tenant serving through the control->data plane bridge.

A ServingFleet hosts engine replicas as WorkUnits: the SuperScheduler
places ``engine-<i>`` units on nodes, each node agent's provider spawns a
live GenerationEngine with a dedicated drive thread, and tenant requests
flow through the shared per-tenant WRR SlotScheduler — so the bursty
tenant's flood cannot starve the steady tenant's admissions, and
per-tenant TTFT / token throughput land in the framework's metrics
registry (where the autoscaler's engine-replica actuator reads them).

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import VirtualClusterFramework
from repro.models import init_params
from repro.serving import GenerationEngine, ServingFleet


def main():
    cfg = reduced(get_config("qwen2-7b"), n_layers=2, d_model=64, vocab=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    fleet = ServingFleet(
        lambda: GenerationEngine(cfg, params, slots=4, max_len=64,
                                 compute_dtype=jnp.float32),
        replicas=2, scan_interval=0.1)

    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=3600)
    fleet.attach(fw)
    with fw:
        # tenants register from their control planes; the steady tenant
        # gets double WRR weight at the admission scheduler
        bursty = fw.add_tenant("bursty")
        steady = fw.add_tenant("steady", weight=2)
        fleet.register_tenant(bursty)
        fleet.register_tenant(steady)
        while fleet.live_replicas() < 2:
            time.sleep(0.01)
        for u in fw.super_api.list("WorkUnit", "vc-serving"):
            print(f"[fleet] {u.metadata.name} scheduled on "
                  f"{u.status.node or '?'}")

        rng = np.random.default_rng(0)
        uids = {}
        t0 = time.monotonic()
        # bursty tenant: 12 requests at once; steady: 4 paced
        for _ in range(12):
            uid = fleet.submit("bursty", rng.integers(0, cfg.vocab, 12),
                               max_new_tokens=8)
            uids[uid] = "bursty"
        for _ in range(4):
            uid = fleet.submit("steady", rng.integers(0, cfg.vocab, 12),
                               max_new_tokens=8)
            uids[uid] = "steady"
        done = fleet.wait_completed(len(uids), timeout=120)
        wall = time.monotonic() - t0

        by_tenant = {}
        for uid, req in done.items():
            by_tenant.setdefault(uids[uid], []).append(
                req.first_token_at - req.submitted_at)
        toks = sum(len(r.tokens) for r in done.values())
        print(f"served {len(done)} requests / {toks} tokens in {wall:.2f}s "
              f"({toks / wall:.0f} tok/s)")
        for name, ttfts in sorted(by_tenant.items()):
            print(f"  {name:7s}: {len(ttfts)} reqs, "
                  f"mean TTFT {sum(ttfts) / len(ttfts) * 1e3:.1f}ms")
        snap = fw.metrics.snapshot()
        for t in ("bursty", "steady"):
            s = snap["summaries"].get(
                f"serving_ttft_seconds{{tenant={t}}}", {})
            print(f"  metrics[{t}]: ttft_count={s.get('count', 0):.0f} "
                  f"tokens="
                  f"{snap['counters'].get(f'serving_tokens_total{{tenant={t}}}', 0):.0f}")
    print("done")


if __name__ == "__main__":
    main()
