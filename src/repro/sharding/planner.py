"""Sharding planner: picks a legal, efficient layout per (arch, shape, mesh).

Strategies (auto-selected, overridable):

- **tp_heads** — Megatron-style tensor parallelism: attention heads sharded
  over "model" (KV heads sharded too when divisible, else replicated à la
  GQA-with-tp>kv), FFN/vocab/experts sharded over "model", residual stream
  sequence-sharded over "model" between blocks (Megatron sequence
  parallelism: the partitioner materializes the all-gather/reduce-scatter
  pair at block entry/exit).

- **context** — fallback when n_heads % model != 0 (qwen2-7b: 28 heads,
  qwen2.5-14b: 40 heads): attention is context-parallel — q sequence-sharded
  over "model", K/V all-gathered; everything else as tp_heads.

- **decode** — serving steps: S=1 kills seq sharding, so the KV cache is
  sharded along its *sequence* dim over "model" and decode attention runs a
  flash-decode partial-softmax combine (shard_map psum of (acc, m, l)) —
  works for every head count and turns the HBM-bound cache read into 1/16th
  per chip.

Training defaults to FSDP over the "data" axis for params/optimizer ("embed"
param axis additionally sharded over data), since fp32 AdamW state for the
30-52B configs cannot fit model-sharded-only.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import cache_axes as model_cache_axes
from ..models import param_axes as model_param_axes
from ..models.config import ModelConfig, ShapeConfig
from ..training.optimizer import opt_state_axes
from .api import ShardingRules


@dataclass
class Plan:
    rules: ShardingRules
    strategy: str
    notes: List[str] = field(default_factory=list)

    # -- sharding trees ------------------------------------------------------

    def tree_sharding(self, axes_tree: Any) -> Any:
        return jax.tree.map(
            lambda names: NamedSharding(self.rules.mesh,
                                        self.rules.spec(names)),
            axes_tree, is_leaf=lambda t: isinstance(t, tuple))

    def named(self, *names: Optional[str]) -> NamedSharding:
        return self.rules.sharding(names)


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Greedy maximal prefix of (pod, data) whose product divides the batch."""
    axes: Tuple[str, ...] = ()
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and _divides(global_batch, prod * mesh.shape[a]):
            axes += (a,)
            prod *= mesh.shape[a]
    return axes


def plan_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
             fsdp: Optional[bool] = None,
             strategy: Optional[str] = None,
             seq_shard: bool = True) -> Plan:
    model = mesh.shape.get("model", 1)
    data_axes = _batch_axes(mesh, shape.global_batch)
    heads_div = _divides(cfg.n_heads, model)
    kv_div = _divides(cfg.n_kv_heads, model)
    mode = shape.kind                    # train | prefill | decode
    if fsdp is None:
        fsdp = mode == "train"
    notes: List[str] = []

    if strategy is None:
        if mode == "decode":
            strategy = "decode"
        elif heads_div:
            strategy = "tp_heads"
        else:
            strategy = "context"
            notes.append(
                f"{cfg.name}: {cfg.n_heads} heads % model={model} != 0 -> "
                f"context-parallel attention (KV all-gathered)")

    seq_ok = seq_shard and _divides(shape.seq_len, model) and mode != "decode"

    bindings: Dict[str, Any] = {
        # params
        "vocab": "model",
        "mlp": "model",
        "expert": "model" if _divides(cfg.n_experts, model) or not cfg.is_moe
        else None,
        "inner": "model" if _divides(cfg.mamba_d_inner, model) else None,
        "heads_flat": "model" if heads_div and strategy != "decode" else None,
        "kv_flat": "model" if heads_div and kv_div and strategy != "decode"
        else None,
        "embed": ("data" if fsdp and "data" in mesh.axis_names else
                  ("model" if mode == "decode" else None)),
        "layers": None,
        # activations
        "batch": data_axes if data_axes else None,
        "seq": "model" if seq_ok else None,
        "act_seq": None,
        "kv_seq": None,
        "attn_seq": "model" if strategy == "context" and seq_ok else None,
        "heads": "model" if heads_div and strategy == "tp_heads" else None,
        "kv_heads": "model" if heads_div and kv_div and strategy == "tp_heads"
        else None,
        "cache_seq": "model" if mode in ("prefill", "decode") else None,
        # moe dispatch token sharding
        "moe_tokens": (data_axes + ("model",)) if seq_ok else
        (data_axes if data_axes else None),
    }
    if cfg.is_moe and not _divides(cfg.n_experts, model):
        notes.append(f"{cfg.name}: {cfg.n_experts} experts % model={model} "
                     f"!= 0 -> experts replicated")
    if not data_axes:
        notes.append(f"global_batch={shape.global_batch} not divisible by "
                     f"data axes -> batch replicated")
    if mode == "decode":
        notes.append("decode: KV-cache sequence-sharded over model + "
                     "flash-decode partial-softmax combine; weights "
                     "row-parallel over model (embed dim), nothing "
                     "replicated")

    rules = ShardingRules(mesh, bindings)
    return Plan(rules=rules, strategy=strategy, notes=notes)


# ------------------------------------------------------------- step shardings

def train_shardings(plan: Plan, cfg: ModelConfig) -> Dict[str, Any]:
    axes = model_param_axes(cfg)
    p_shard = plan.tree_sharding(axes)
    o_axes = opt_state_axes(axes)
    o_shard = plan.tree_sharding(
        jax.tree.map(lambda t: t, o_axes, is_leaf=lambda t: isinstance(t, tuple)))
    batch = {
        "tokens": plan.named("batch", "seq"),
        "mask": plan.named("batch", "seq"),
    }
    if cfg.frontend == "vit_stub":
        batch["patches"] = plan.named("batch", None, None)
    elif cfg.frontend == "speech_stub":
        batch["frames"] = plan.named("batch", "seq", None)
    return {"params": p_shard, "opt": o_shard, "batch": batch,
            "replicated": NamedSharding(plan.rules.mesh, P())}


def serve_shardings(plan: Plan, cfg: ModelConfig) -> Dict[str, Any]:
    axes = model_param_axes(cfg)
    p_shard = plan.tree_sharding(axes)
    c_axes = model_cache_axes(cfg)
    c_shard = jax.tree.map(
        lambda names: plan.named(*names[:]),
        c_axes, is_leaf=lambda t: isinstance(t, tuple))
    # stacked cache: leading dim is "layers"
    return {"params": p_shard, "cache": c_shard,
            "tokens": plan.named("batch", None),
            "lengths": plan.named("batch"),
            "frames": plan.named("batch", "seq", None),
            "patches": plan.named("batch", None, None),
            "replicated": NamedSharding(plan.rules.mesh, P())}
