"""Upward status/event pipeline: sharded, coalescing, batched (paper §IV).

The paper's syncer is bidirectional; upward synchronization (super-cluster
status -> tenant control planes) is the half tenants actually *watch* — a
tenant polls its own apiserver for WorkUnit phases, Service endpoints, and
Events, so upward latency is directly tenant-visible (the Fig.8 breakdown
carries the UWS queue as a first-class phase). This module mirrors the
downward path's architecture on the upward axis:

- **Events** (:class:`EventRecorder`): node agents record Kubernetes-style
  :class:`~repro.core.objects.Event` objects on WorkUnit phase transitions
  and node heartbeats. Repeats of the same (involved, reason, component)
  tuple compress into one object (``count`` increments, ``last_timestamp``
  advances) — kubelet event-aggregation semantics. Events are synced upward
  so tenants can "kubectl get events" inside their own control planes.
- **Upward shards** (:class:`UpwardShard`): the shared upward FIFO is
  replaced by tenant-hash shards on a consistent-hash
  :class:`~repro.core.ring.ShardRing` — each shard owns a per-tenant
  :class:`~repro.core.fairqueue.FairWorkQueue` (WRR dispatch, Fig.11
  fairness on the upward axis too) and its own super-API client, and runs
  its workers on the shared cooperative executor.
- **Latest-wins coalescing + batched writes** (:meth:`UpwardPipeline.
  reconcile_fast`): a key is queued at most once (fair-queue dedup), and
  reconcile reads the *current* super informer cache — N rapid status flaps
  collapse into one write of the latest state. Same-tenant bursts drain as
  one batch and commit with ONE ``update_status_batch`` per tenant plane
  (``ObjectStore.update_status_many``: a single lock round), with Events
  created/bumped the same way.
- **Elasticity** (:meth:`UpwardPipeline.resize_locked`, driven by
  ``Syncer.resize_upward_shards``): the autoscaler's third actuator grows
  and shrinks the upward fleet from upward queue depth and sync latency,
  live-migrating only ~1/N tenants per step — exactly like the downward
  fleet.
"""
from __future__ import annotations

import hashlib
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from .fairqueue import FairWorkQueue
from .objects import Event, deepcopy_obj, status_equal
from .ring import ShardRing
from .runtime import Controller, RetryLater
from .store import AlreadyExistsError, ConflictError, NotFoundError
from .trace import TRACEPARENT_KEY, sampled_carrier

UpKey = Tuple[str, str, str]           # (kind, super_ns, name)


def event_name(involved_kind: str, involved_name: str, reason: str,
               component: str) -> str:
    """Deterministic dedup name: repeats of one (involved, reason, source)
    tuple always address the same Event object."""
    h = hashlib.sha256(
        f"{involved_kind}/{involved_name}/{reason}/{component}"
        .encode()).hexdigest()[:10]
    return f"{involved_name}.{h}"


class EventRecorder:
    """Records deduplicated Events against one apiserver (kubelet analogue).

    ``record`` is a read-modify-write: an existing Event for the same
    (involved object, reason, component) gets ``count += 1`` and a fresh
    ``last_timestamp`` (compression); a first occurrence creates the object.
    Safe under concurrent recorders — a create race falls back to the bump.
    """

    def __init__(self, api: Any, component: str, host: str = ""):
        self.api = api
        self.component = component
        self.host = host
        self.recorded = 0

    def record(self, involved_kind: str, namespace: str, involved_name: str,
               reason: str, message: str = "", type: str = "Normal") -> Any:
        name = event_name(involved_kind, involved_name, reason,
                          self.component)
        now = time.time()

        def bump(e: Event) -> None:
            e.count += 1
            e.last_timestamp = now
            e.message = message
            e.type = type

        self.recorded += 1
        try:
            return self.api.update_status("Event", namespace, name, bump)
        except NotFoundError:
            pass
        ev = Event()
        ev.metadata.name = name
        ev.metadata.namespace = namespace
        ev.involved_kind = involved_kind
        ev.involved_namespace = namespace
        ev.involved_name = involved_name
        ev.reason = reason
        ev.message = message
        ev.type = type
        ev.source_component = self.component
        ev.source_host = self.host
        ev.count = 1
        ev.first_timestamp = ev.last_timestamp = now
        try:
            return self.api.create(ev)
        except AlreadyExistsError:   # lost the create race: bump instead
            return self.api.update_status("Event", namespace, name, bump)


class UpwardShard(Controller):
    """One upward shard: a per-shard fair queue + workers serving the
    tenants hashed onto it, with its OWN super-API client (dedicated token
    bucket) for the reads the status projection needs.

    Items are ``(tenant, (kind, super_ns, name))``. A key that flaps while
    queued is deduplicated by the fair queue and reconciled once from the
    *latest* informer-cache state — the per-object latest-wins coalescing.
    """

    def __init__(self, syncer: Any, shard_id: int, *, workers: int,
                 fair: bool, batch_size: int):
        super().__init__(f"syncer-uws-{shard_id}",
                         queue=FairWorkQueue(f"upward-{shard_id}", fair=fair),
                         workers=workers, batch_size=batch_size,
                         retry_on=(ConflictError, RetryLater), drop_on=())
        self.syncer = syncer
        self.shard_id = shard_id
        self.api = syncer.super_api.client(f"uws-{shard_id}")
        # shards created after wiring (resize) inherit the live meter
        self.queue.meter = syncer._meter

    def _retry_queue(self, item: Any) -> Any:
        """Retries re-enter the tenant's CURRENT upward shard (a resize may
        have migrated the tenant while the item was in flight)."""
        reg = self.syncer.tenants.get(item[0])   # GIL-atomic dict read
        return reg.upward_shard.queue if reg is not None else self.queue

    def _stamp_dequeue(self, kind: str, super_ns: str, name: str,
                       now: Optional[float] = None) -> Optional[Any]:
        if kind != "WorkUnit":
            return None
        sy = self.syncer
        resolved = sy._resolve_super_ns(super_ns)
        if resolved is None:
            return None
        tl = sy.metrics.timeline(resolved[0], resolved[1], name)
        if tl.uws_dequeue == 0.0 and tl.super_ready > 0.0:
            tl.uws_dequeue = now if now is not None else time.time()
        return tl

    def reconcile(self, item: Any) -> None:
        tenant, (kind, super_ns, name) = item
        tl = self._stamp_dequeue(kind, super_ns, name)
        self.syncer.upward.reconcile_one(tenant, kind, super_ns, name,
                                         api=self.api)
        # stamped AFTER a successful sync only: a raise above means the item
        # will be retried, and stamping now would undercount the real
        # UWS-Process phase in the fig7/fig8 latency breakdowns
        if tl is not None and tl.uws_done == 0.0 and tl.super_ready > 0.0:
            tl.uws_done = time.time()

    def reconcile_batch(self, items: List[Any]) -> None:
        """Coalesce a same-tenant burst: latest-wins status computation off
        the informer caches plus ONE batched tenant-plane write; leftovers
        (unknown kinds, create races) take the authoritative per-item path."""
        if len(items) == 1:
            return self._reconcile_one(items[0])
        tenant = items[0][0]
        now = time.time()
        tls = {}
        for _, (kind, super_ns, name) in items:
            tl = self._stamp_dequeue(kind, super_ns, name, now)
            if tl is not None:
                tls[(kind, super_ns, name)] = tl
        t0 = time.monotonic()
        try:
            fast, slow = self.syncer.upward.reconcile_fast(
                tenant, [key for _, key in items], api=self.api)
        except Exception:
            # fast path failed as a unit; fall back to per-item reconciles
            # below, but surface the failure in metrics
            self.metrics.inc("fast_path_errors", controller=self.name)
            fast, slow = [], [key for _, key in items]
        dur = time.monotonic() - t0
        done = time.time()
        fast_items = []
        for key in fast:
            fast_items.append((tenant, key))
            tl = tls.get(key)
            if tl is not None and tl.uws_done == 0.0 and tl.super_ready > 0.0:
                tl.uws_done = done
        if fast_items:
            # batch the bookkeeping too: one lock round each instead of a
            # limiter + two metric + one queue lock round PER KEY
            self.limiter.forget_many(fast_items)
            self.metrics.inc("reconcile_total", float(len(fast_items)),
                             controller=self.name)
            self.metrics.observe_n("reconcile_seconds", dur / len(items),
                                   n=len(fast_items), controller=self.name)
            self.queue.done_batch(fast_items)
        for key in slow:
            self._reconcile_one((tenant, key))


class UpwardPipeline:
    """The upward fleet: shard controllers + ring + reconcile logic.

    Owned by :class:`~repro.core.syncer.Syncer` (which provides the tenant
    registry, namespace resolution, vNode manager, and super informers);
    this class owns everything upward-specific so the axis can be reasoned
    about, resized, and benchmarked on its own.
    """

    def __init__(self, syncer: Any, *, shards: int, total_workers: int,
                 fair: bool, batch_size: int, ring_vnodes: int = 64):
        self.syncer = syncer
        self.num_shards = max(1, int(shards))
        self.fair = fair
        self.batch_size = max(1, int(batch_size))
        self.ring_vnodes = max(1, int(ring_vnodes))
        self.ring = ShardRing(self.num_shards, self.ring_vnodes)
        per_shard = max(1, int(total_workers) // self.num_shards)
        self.controllers: List[UpwardShard] = [
            UpwardShard(syncer, i, workers=per_shard, fair=fair,
                        batch_size=self.batch_size)
            for i in range(self.num_shards)]

    # ------------------------------------------------------------- routing

    def shard_for_uid(self, uid: str) -> UpwardShard:
        return self.controllers[self.ring.shard_for(uid)]

    def enqueue(self, kind: str, super_ns: str, name: str) -> bool:
        """Route one super-side key onto its tenant's current upward shard.
        Unresolvable namespaces (cluster-scoped events, foreign objects) are
        skipped. Mirrors the downward handlers' migration re-check: if a
        resize races the add, re-add on the new shard (dedup makes the
        double add harmless)."""
        sy = self.syncer
        resolved = sy._resolve_super_ns(super_ns)
        if resolved is None:
            return False
        tenant = resolved[0]
        while True:
            reg = sy.tenants.get(tenant)     # GIL-atomic dict read
            if reg is None:
                return False
            shard = reg.upward_shard
            shard.queue.add(tenant, (kind, super_ns, name))
            if reg.upward_shard is shard:
                return True

    def coalesced_total(self) -> int:
        """Keys absorbed by queue dedup (flaps that never cost a write)."""
        return sum(c.queue.deduped for c in self.controllers)

    # ------------------------------------------------------------ resizing

    def resize_locked(self, n: int) -> Dict[str, int]:
        """Resize the upward fleet; caller holds the syncer's resize lock.
        Mirrors the downward resize minus informer handover (super informers
        are shared, attached to shard 0, and shard 0 never retires)."""
        sy = self.syncer
        if n == self.num_shards:
            return {}
        registry = self.controllers[0].metrics
        running = any(c.running for c in self.controllers)
        per_shard = self.controllers[0].workers
        while len(self.controllers) < n:
            i = len(self.controllers)
            c = UpwardShard(sy, i, workers=per_shard, fair=self.fair,
                            batch_size=self.batch_size)
            c.metrics = registry
            c.executor = sy.executor
            self.controllers.append(c)
            sy.controllers.append(c)
            if running:
                c.start()   # must run before tenants route onto it
            if sy.manager is not None:
                sy.manager.add(c)
        new_ring = ShardRing(n, self.ring_vnodes)
        with sy._tenants_lock:
            regs = list(sy.tenants.values())
        moved: Dict[str, int] = {}
        for reg in regs:
            target = new_ring.shard_for(reg.uid)
            if target == reg.upward_shard.shard_id:
                continue
            self._migrate_tenant(reg, self.controllers[target])
            moved[reg.plane.name] = target
        self.ring = new_ring
        self.num_shards = n
        if len(self.controllers) > n:       # shrink: now-empty tail shards
            for c in self.controllers[n:]:
                c.stop()
                sy.controllers.remove(c)
                if sy.manager is not None:
                    sy.manager.remove(c)
            del self.controllers[n:]
        return moved

    def _migrate_tenant(self, reg: Any, new_shard: UpwardShard) -> None:
        tenant = reg.plane.name
        old_shard = reg.upward_shard
        new_shard.queue.register_tenant(tenant, reg.plane.weight)
        reg.upward_shard = new_shard    # enqueue() resolves via reg
        pending = old_shard.queue.drain_tenant(tenant)
        old_shard.queue.unregister_tenant(tenant)
        for key in pending:
            new_shard.queue.add(tenant, key)
        # clear any ghost re-registration from a racing enqueue (see the
        # downward migration's identical second pass)
        old_shard.queue.drain_tenant(tenant)
        old_shard.queue.unregister_tenant(tenant)

    # --------------------------------------------------------- reconcilers

    def reconcile_one(self, tenant: str, kind: str, super_ns: str, name: str,
                      api: Optional[Any] = None) -> None:
        """Authoritative per-item upward sync (also the slow path under
        coalescing): super status/event is the source of truth -> project
        back into the tenant plane."""
        sy = self.syncer
        resolved = sy._resolve_super_ns(super_ns)
        if resolved is None:
            return
        tenant_ns = resolved[1]
        with sy._tenants_lock:
            reg = sy.tenants.get(tenant)
        if reg is None:
            return
        inf = sy._super_informers.get(kind)
        super_obj = inf.cache.get(super_ns, name) if inf is not None else None
        if super_obj is None:
            return  # deletion downward is handled by the downward reconciler
        if kind == "WorkUnit":
            self._sync_unit_status_up(reg, tenant_ns, name, super_obj,
                                      api=api)
        elif kind == "Service":
            self._sync_service_up(reg, tenant_ns, name, super_obj)
        elif kind == "Event":
            self._sync_event_up(reg, tenant_ns, name, super_obj)
        sy.metrics.inc_upward()
        m = sy._meter
        if m is not None:
            m.add(tenant, "up_items", 1.0)

    def reconcile_fast(self, tenant: str, keys: List[UpKey],
                       api: Optional[Any] = None
                       ) -> Tuple[List[UpKey], List[UpKey]]:
        """Coalesced upward pass over a same-tenant burst.

        Latest states are read from the super informer caches; unchanged
        objects are skipped (echo suppression), and the rest are committed
        with ONE ``update_status_batch`` per tenant plane — plus one
        ``update_status_batch`` + ``create_batch`` round for Events.
        Returns ``(fast, slow)``: ``slow`` keys (unknown kinds, event create
        races) need the authoritative per-item reconcile.
        """
        sy = self.syncer
        tr = sy.tracer
        t0 = time.monotonic() if tr is not None else 0.0
        traced: List[Tuple[UpKey, Any, str]] = []
        fast: List[UpKey] = []
        slow: List[UpKey] = []
        with sy._tenants_lock:
            reg = sy.tenants.get(tenant)
        if reg is None:
            return list(keys), slow
        status_updates: List[Tuple[str, str, str, Callable]] = []
        status_keys: List[UpKey] = []
        ev_updates: List[Tuple[str, str, str, Callable]] = []
        ev_sources: List[Tuple[UpKey, Any, str]] = []
        synced = 0
        # same-tenant batches share a namespace almost always: memoize the
        # reverse-map hit so a batch costs one resolve, not one per key
        ns_memo: Dict[str, Any] = {}
        for key in keys:
            kind, super_ns, name = key
            resolved = ns_memo.get(super_ns)
            if resolved is None:
                resolved = sy._resolve_super_ns(super_ns)
                ns_memo[super_ns] = resolved if resolved is not None else False
            if resolved is False or resolved is None:
                fast.append(key)        # tenant gone: nothing to project
                continue
            tenant_ns = resolved[1]
            inf = sy._super_informers.get(kind)
            sobj = inf.cache.get(super_ns, name) if inf is not None else None
            if sobj is None:
                fast.append(key)        # deleted upstream: downward cleans up
                continue
            if kind == "WorkUnit":
                status = self._project_unit_status(reg, tenant_ns, name,
                                                   sobj, api=api)
                winf = reg.informers.get("WorkUnit")
                cached = (winf.cache.get(tenant_ns, name)
                          if winf is not None else None)
                if cached is not None and status_equal(cached.status, status):
                    fast.append(key)    # echo: tenant already shows it
                    continue

                def mutate(u: Any, status: Any = status) -> None:
                    u.status = status
                status_updates.append(("WorkUnit", tenant_ns, name, mutate))
                status_keys.append(key)
                if tr is not None:
                    tp = sobj.metadata.annotations.get(TRACEPARENT_KEY)
                    if tp and sampled_carrier(tp):
                        traced.append((key, sobj, tenant_ns))
            elif kind == "Service":
                eps, vip = list(sobj.endpoints), sobj.virtual_ip
                sinf = reg.informers.get("Service")
                cached = (sinf.cache.get(tenant_ns, name)
                          if sinf is not None else None)
                if (cached is not None and cached.endpoints == eps
                        and cached.virtual_ip == vip):
                    fast.append(key)
                    continue

                def mutate(s: Any, eps: Any = eps, vip: str = vip) -> None:
                    s.endpoints = eps
                    s.virtual_ip = vip
                status_updates.append(("Service", tenant_ns, name, mutate))
                status_keys.append(key)
                if tr is not None:
                    tp = sobj.metadata.annotations.get(TRACEPARENT_KEY)
                    if tp and sampled_carrier(tp):
                        traced.append((key, sobj, tenant_ns))
            elif kind == "Event":
                ev_updates.append(("Event", tenant_ns, name,
                                   _event_bump(sobj)))
                ev_sources.append((key, sobj, tenant_ns))
            else:
                slow.append(key)
        if status_updates:
            updated, missing = reg.plane.api.update_status_batch(
                status_updates)
            # missing == tenant deleted it mid-flight: same as the per-item
            # path's NotFound pass — the downward reconciler cleans up
            fast.extend(status_keys)
            synced += len(updated)
            if traced:
                miss = set(missing)
                for key, sobj, t_ns in traced:
                    if (key[0], t_ns, key[2]) not in miss:
                        self._trace_up(sobj, t0, tenant, key[0], t_ns,
                                       key[2], batch=len(keys))
        if ev_updates:
            updated, missing = reg.plane.api.update_status_batch(ev_updates)
            synced += len(updated)
            miss = set(missing)
            creates: List[Event] = []
            create_keys: List[UpKey] = []
            for key, sobj, tenant_ns in ev_sources:
                if ("Event", tenant_ns, key[2]) in miss:
                    creates.append(self._project_event(sobj, tenant_ns))
                    create_keys.append(key)
                else:
                    fast.append(key)
            if creates:
                created, conflicted = reg.plane.api.create_batch(creates)
                synced += len(created)
                lost = {(o.metadata.namespace, o.metadata.name)
                        for o in conflicted}
                for key, obj in zip(create_keys, creates):
                    if (obj.metadata.namespace, obj.metadata.name) in lost:
                        slow.append(key)    # create race: per-item retry
                    else:
                        fast.append(key)
        if synced:
            sy.metrics.inc_upward(synced)
            m = sy._meter
            if m is not None:
                # the whole coalesced burst attributes to its (single)
                # tenant: N landed commits -> N up_items, exactly
                m.add(tenant, "up_items", float(synced))
        return fast, slow

    # -------------------------------------------------------------- tracing

    def _trace_up(self, sobj: Any, t0: float, tenant: str, kind: str,
                  tenant_ns: str, name: str, batch: int = 0) -> None:
        """Record a "syncer.up" child span for a traced object whose status
        just landed in the tenant plane, and — since a landed status IS the
        end of the paper's propagation path — close the pending end-to-end
        span, feeding its duration to the propagation histogram and the
        per-tenant SLO tracker. Echo-suppressed keys never reach here, so
        the e2e span closes on the FIRST real status return only."""
        sy = self.syncer
        tr = sy.tracer
        if tr is None:
            return
        tp = sobj.metadata.annotations.get(TRACEPARENT_KEY)
        if not tp:
            return
        if not sampled_carrier(tp):
            # head-unsampled: nothing was registered at the root, no child
            # can be retained, and the SLO/histogram feeds run on the
            # sampled subset — the unsampled path pays zero tracer calls
            return
        end = time.monotonic()
        attrs: Dict[str, Any] = {"kind": kind, "ns": tenant_ns, "name": name}
        if batch:
            attrs["batch"] = batch
        tr.record_from(tp, "syncer.up", t0, end, tenant=tenant, attrs=attrs)
        root = tr.finish_pending(tp, end)
        if root is None:
            return      # already closed (or never opened here)
        dur = max(0.0, root.end - root.start)
        m = self.controllers[0].metrics
        m.histogram("propagation_seconds").observe(dur)
        m.histogram("propagation_seconds", tenant=tenant).observe(dur)
        if sy.slo is not None:
            sy.slo.observe("propagation", tenant, dur)

    # ------------------------------------------------------ kind projectors

    def _project_unit_status(self, reg: Any, tenant_ns: str, name: str,
                             super_obj: Any,
                             api: Optional[Any] = None) -> Any:
        """Super WorkUnit status with the physical node mapped to a vNode."""
        sy = self.syncer
        vnode_name = ""
        if super_obj.status.node:
            node_inf = sy._super_informers.get("Node")
            pnode = None
            if node_inf is not None:
                pnode = node_inf.cache.get("", super_obj.status.node)
            if pnode is None:
                try:
                    pnode = (api or sy.super_api).get(
                        "Node", "", super_obj.status.node)
                except NotFoundError:
                    pnode = None
            if pnode is not None:
                vnode_name = sy.vnodes.bind(reg.plane, pnode, tenant_ns, name)
        status = deepcopy_obj(super_obj.status)
        if vnode_name:
            status.node = vnode_name
        return status

    def _sync_unit_status_up(self, reg: Any, tenant_ns: str, name: str,
                             super_obj: Any,
                             api: Optional[Any] = None) -> None:
        t0 = time.monotonic() if self.syncer.tracer is not None else 0.0
        status = self._project_unit_status(reg, tenant_ns, name, super_obj,
                                           api=api)
        winf = reg.informers.get("WorkUnit")
        cached = winf.cache.get(tenant_ns, name) if winf is not None else None
        if cached is not None and status_equal(cached.status, status):
            return

        def mutate(u: Any) -> None:
            u.status = status

        try:
            reg.plane.api.update_status("WorkUnit", tenant_ns, name, mutate)
        except NotFoundError:
            pass  # tenant deleted it mid-flight; scan/downward will clean up
        else:
            self._trace_up(super_obj, t0, reg.plane.name, "WorkUnit",
                           tenant_ns, name)

    def _sync_service_up(self, reg: Any, tenant_ns: str, name: str,
                         super_obj: Any) -> None:
        t0 = time.monotonic() if self.syncer.tracer is not None else 0.0
        eps = list(super_obj.endpoints)
        vip = super_obj.virtual_ip
        sinf = reg.informers.get("Service")
        cached = sinf.cache.get(tenant_ns, name) if sinf is not None else None
        if (cached is not None and cached.endpoints == eps
                and cached.virtual_ip == vip):
            return

        def mutate(s: Any) -> None:
            s.endpoints = eps
            s.virtual_ip = vip

        try:
            reg.plane.api.update_status("Service", tenant_ns, name, mutate)
        except NotFoundError:
            pass
        else:
            self._trace_up(super_obj, t0, reg.plane.name, "Service",
                           tenant_ns, name)

    def _sync_event_up(self, reg: Any, tenant_ns: str, name: str,
                       super_obj: Any) -> None:
        """Project one super Event into the tenant plane (latest-wins:
        count/lastTimestamp compression carries over verbatim)."""
        try:
            reg.plane.api.update_status("Event", tenant_ns, name,
                                        _event_bump(super_obj))
            return
        except NotFoundError:
            pass
        ev = self._project_event(super_obj, tenant_ns)
        try:
            reg.plane.api.create(ev)
        except AlreadyExistsError:
            reg.plane.api.update_status("Event", tenant_ns, name,
                                        _event_bump(super_obj))

    @staticmethod
    def _project_event(super_obj: Any, tenant_ns: str) -> Event:
        ev = deepcopy_obj(super_obj)
        ev.metadata.namespace = tenant_ns
        ev.metadata.uid = ""
        ev.metadata.resource_version = 0
        ev.metadata.creation_timestamp = 0.0
        ev.involved_namespace = tenant_ns
        return ev


def _event_bump(super_obj: Any) -> Callable[[Event], None]:
    """Mutator copying the super event's compressed counters onto the
    tenant copy (latest wins — never an increment, so replays are safe)."""
    count = super_obj.count
    last = super_obj.last_timestamp
    message = super_obj.message
    type_ = super_obj.type

    def mutate(e: Event) -> None:
        e.count = count
        e.last_timestamp = last
        e.message = message
        e.type = type_
    return mutate
