"""Quickstart: a multi-tenant VirtualCluster in ~40 lines.

Two tenants get dedicated control planes on a shared 4-node super cluster;
each submits WorkUnits with identical names — full API compatibility, no
collisions, vNode views preserved. Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import json
import time
import urllib.error
import urllib.request

from repro.core import VirtualClusterFramework


def main():
    # autoscale=True: the closed-loop autoscaler (sixth controller) sizes
    # the downward shard fleet and the executor pool from live load
    # metering/audit: per-tenant usage attribution + request audit trail,
    # surfaced at /usage and /audit (both default off, ~zero cost off)
    fw = VirtualClusterFramework(num_nodes=4, scan_interval=5.0,
                                 heartbeat_interval=2.0, autoscale=True,
                                 metering=True, audit=True)
    with fw:
        # metrics over HTTP: counters/summaries/gauges as JSON (stdlib only)
        port = fw.serve_metrics()
        print(f"metrics: http://127.0.0.1:{port}/metrics  "
              f"health: http://127.0.0.1:{port}/healthz")
        # tenants are provisioned by the tenant operator from VC objects
        acme = fw.add_tenant("acme", weight=2)
        globex = fw.add_tenant("globex", weight=1)
        print("tenants provisioned:",
              [vc.metadata.name
               for vc in fw.super_api.list("VirtualClusterCR")])

        # both tenants use the same namespace/name — isolated control planes
        for plane in (acme, globex):
            unit = fw.make_unit("train-job", "default", chips=2,
                                arch="tiny-dense", shape="train_4k")
            fw.submit(plane, unit)

        for plane in (acme, globex):
            u = fw.wait_ready(plane, "default", "train-job", timeout=30)
            print(f"[{plane.name}] train-job -> {u.status.phase} on "
                  f"vNode {u.status.node}")
            print(f"[{plane.name}] vNodes visible: "
                  f"{[v.metadata.name for v in plane.api.list('VirtualNode')]}")

        # the super cluster sees namespace-prefixed copies (paper §III-B(2))
        print("super-cluster namespaces:",
              [n.metadata.name for n in fw.super_api.list("Namespace")])

        # logs flow through the vn-agent with credential-based identity
        u = acme.api.get("WorkUnit", "default", "train-job")
        log = fw.vn_agent.logs(acme.api.credential, u.status.node,
                               "default", "train-job")
        print("acme logs via vn-agent:", log.strip())

        # tenant-visible Events: the node agents record WorkUnit phase
        # transitions (and node heartbeats) as deduplicated Events in the
        # super cluster; the upward pipeline syncs each tenant's events —
        # dedup counts included — into its own control plane, so this is
        # the tenant's "kubectl get events"
        deadline = time.monotonic() + 5.0
        while not acme.api.list("Event", "default") \
                and time.monotonic() < deadline:
            time.sleep(0.05)      # the upward sync is asynchronous
        for ev in acme.api.list("Event", "default"):
            print(f"[acme] event {ev.reason} x{ev.count} "
                  f"{ev.involved_kind}/{ev.involved_name}: {ev.message}")

        # tenant deletion cascades: super copies and vNodes are GC'd
        acme.api.delete("WorkUnit", "default", "train-job")
        time.sleep(0.5)
        print("super WorkUnits after acme delete:",
              len(fw.super_api.list("WorkUnit")))

        # every controller runs on the shared runtime: one health map and
        # one metrics registry for the whole control plane, served over HTTP
        try:
            health = json.load(urllib.request.urlopen(
                f"http://127.0.0.1:{port}/healthz"))
        except urllib.error.HTTPError as e:   # 503 = some controller down
            health = json.load(e.fp)
        print("controller health (HTTP):", all(health["controllers"].values()))
        # the autoscaler's loop state rides /healthz: last decision, live
        # targets, cooldown remaining — a wedged loop is visible here
        scaler = health["autoscaler"]
        print("autoscaler targets:", scaler["targets"],
              "last decision:", scaler["last_decision"])
        snap = json.load(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics"))
        reconciles = {k: int(v) for k, v in snap["counters"].items()
                      if k.startswith("reconcile_total")}
        print("reconciles by controller:", reconciles)
        # the whole control plane — informers, workers, scans for every
        # tenant — multiplexes onto one fixed-size cooperative pool
        print("executor:", {k: int(v) for k, v in snap["gauges"].items()
                            if k.startswith("executor")})

        # who used what: /usage attributes every resource axis per tenant
        # (lifetime totals + rolling window) and scores noisy neighbors
        usage = json.load(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/usage"))
        acme_usage = usage["totals"].get("acme", {})
        print("acme usage:",
              {k: round(v, 1) for k, v in sorted(acme_usage.items())})
        print("noisy neighbors (score >= "
              f"{usage['noisy_threshold']}):",
              [f"{n['tenant']}@{n['score']:.2f}" for n in usage["noisy"]])
        # and who did what: the audit trail, filterable per tenant/verb
        audit = json.load(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/audit?tenant=acme&verb=delete"))
        for rec in audit["records"]:
            print(f"[audit] {rec['tenant']} {rec['verb']} "
                  f"{rec['kind']}/{rec['name']} -> {rec['outcome']}")
    print("done")


if __name__ == "__main__":
    main()
