"""Sharding planner: strategy selection, divisibility fallbacks, spec
generation (no devices needed — uses an abstract mesh)."""
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs import REGISTRY, get_shape
from repro.sharding.api import ShardingRules
from repro.sharding.planner import plan_for

MESH1 = abstract_mesh((16, 16), ("data", "model"))
MESH2 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_heads_divisible_uses_tp_heads():
    plan = plan_for(REGISTRY["yi-9b"], get_shape("train_4k"), MESH1)
    assert plan.strategy == "tp_heads"
    assert plan.rules.bindings["heads"] == "model"


def test_heads_indivisible_falls_back_to_context():
    for arch in ("qwen2-7b", "qwen2.5-14b"):
        plan = plan_for(REGISTRY[arch], get_shape("train_4k"), MESH1)
        assert plan.strategy == "context", arch
        assert plan.rules.bindings["heads"] is None
        assert plan.rules.bindings["attn_seq"] == "model"
        assert any("context-parallel" in n for n in plan.notes)


def test_decode_strategy_shards_cache_seq():
    plan = plan_for(REGISTRY["qwen2-7b"], get_shape("decode_32k"), MESH1)
    assert plan.strategy == "decode"
    assert plan.rules.bindings["cache_seq"] == "model"
    assert plan.rules.bindings["embed"] == "model"   # row-parallel weights
    assert plan.rules.bindings["seq"] is None


def test_train_uses_fsdp_embed_on_data():
    plan = plan_for(REGISTRY["jamba-v0.1-52b"], get_shape("train_4k"), MESH1)
    assert plan.rules.bindings["embed"] == "data"


def test_batch_axes_multi_pod():
    plan = plan_for(REGISTRY["qwen2-7b"], get_shape("train_4k"), MESH2)
    assert plan.rules.bindings["batch"] == ("pod", "data")


def test_batch_of_one_not_sharded():
    plan = plan_for(REGISTRY["rwkv6-7b"], get_shape("long_500k"), MESH1)
    assert plan.rules.bindings["batch"] is None
    assert any("batch replicated" in n for n in plan.notes)


def test_moe_expert_axis():
    plan = plan_for(REGISTRY["qwen3-moe-30b-a3b"], get_shape("train_4k"),
                    MESH1)
    assert plan.rules.bindings["expert"] == "model"
    assert plan.rules.bindings["moe_tokens"] == ("data", "model")


def test_rules_spec_dedupes_repeated_axes():
    rules = ShardingRules(MESH1, {"batch": "data", "seq": "model",
                                  "mlp": "model"})
    # "model" may appear once: second use is dropped
    spec = rules.spec(("batch", "seq", "mlp"))
    assert spec == P("data", "model")


def test_rules_spec_trims_trailing_none():
    rules = ShardingRules(MESH1, {"batch": "data"})
    assert rules.spec(("batch", None, None)) == P("data")


def test_spec_multi_axis_binding():
    rules = ShardingRules(MESH2, {"batch": ("pod", "data")})
    assert rules.spec(("batch", None)) == P(("pod", "data"))
