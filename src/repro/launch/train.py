"""Training launcher.

Local mode (default): really trains --arch (reduced or full) on the host
devices with the data pipeline, checkpointing and restart.
Production mode (--dry-run): lowers/compiles the sharded step for the
16x16 / 2x16x16 mesh instead (no allocation) — see launch/dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-dense")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the arch to a CPU-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..ckpt import CheckpointManager
    from ..configs import get_config, reduced
    from ..data import DataConfig, Prefetcher, SyntheticTokens
    from ..models import init_params
    from ..models.config import ShapeConfig
    from ..training import OptimizerConfig, make_opt_state, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("local", args.seq, args.batch, "train")
    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=args.steps // 10,
                              total_steps=args.steps)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"tokens/step={shape.tokens}")
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches))
    opt = make_opt_state(params)

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume and mgr.latest_step() is not None:
            (params, opt), start_step = mgr.restore((params, opt))
            print(f"resumed from step {start_step}")

    data = SyntheticTokens(cfg, shape, DataConfig(seed=args.seed))
    it = Prefetcher(iter(data), depth=2)
    t0 = time.monotonic()
    tokens_done = 0
    for i, batch in zip(range(start_step, args.steps), it):
        params, opt, metrics = step_fn(params, opt, batch)
        tokens_done += int(metrics["tokens"])
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            dt = time.monotonic() - t0
            print(f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"tok/s={tokens_done/dt:.0f}", flush=True)
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, (params, opt))
    if mgr:
        mgr.save(args.steps, (params, opt), block=True)
    it.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
