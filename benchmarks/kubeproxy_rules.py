"""§IV-E: enhanced-kubeproxy (MeshRouter) rule-injection latency.

Paper setup: 100 services created beforehand; 30 units on one node; measure
the extra latency from injecting 100 routing rules into each guest table
before the workload starts (init gate), and the periodic reconcile scan time.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

from repro.core import Namespace, Service
from .common import make_framework


def run(full: bool = False) -> List[Dict]:
    n_services = 100
    n_units = 30
    fw = make_framework(4)
    fw.start()
    try:
        plane = fw.add_tenant("svc-bench")
        ns = Namespace()
        ns.metadata.name = "bench"
        plane.api.create(ns)
        for s in range(n_services):
            svc = Service()
            svc.metadata.name = f"svc{s:03d}"
            svc.metadata.namespace = "bench"
            svc.virtual_ip = f"10.96.{s // 256}.{s % 256}"
            svc.endpoints = [f"ep{s}a", f"ep{s}b"]
            plane.api.create(svc)
        # wait for services to sync down
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if sum(1 for s in fw.super_api.list("Service")) >= n_services:
                break
            time.sleep(0.02)

        t0 = time.monotonic()
        for j in range(n_units):
            unit = fw.make_unit(f"u{j:03d}", "bench", chips=0, init_gate=True)
            plane.api.create(unit)
        fw.wait_all_ready(plane, "bench", n_units, timeout=120)
        gated_total = time.monotonic() - t0

        # per-unit injection latency: creation -> all rules present
        inject_lats: List[float] = []
        for u in fw.super_api.list("WorkUnit"):
            table = fw.router.table(u.metadata.uid)
            if table is None or len(table) < n_services:
                continue
            last_inject = max(table.injected_at.values())
            inject_lats.append(last_inject - u.metadata.creation_timestamp)

        t0 = time.monotonic()
        checked = fw.router.scan_once()
        scan_s = time.monotonic() - t0

        rec = {
            "name": "kubeproxy/inject",
            "services": n_services, "units": n_units,
            "gated_total_s": gated_total,
            "inject_mean_s": statistics.mean(inject_lats) if inject_lats else 0.0,
            "inject_p99_s": (sorted(inject_lats)[int(len(inject_lats) * .99)]
                             if inject_lats else 0.0),
            "rules_injected": fw.router.rules_injected,
            "scan_units": checked, "scan_s": scan_s,
        }
        print(f"  kubeproxy: inject mean {rec['inject_mean_s']*1e3:.0f}ms "
              f"({fw.router.rules_injected} rules), scan {n_units} units "
              f"{scan_s*1e3:.0f}ms", flush=True)
        return [rec]
    finally:
        fw.stop()
