"""Generate markdown tables for EXPERIMENTS.md from result JSONs.

    PYTHONPATH=src python -m benchmarks.make_tables > results/tables.md
"""
from __future__ import annotations

import json
import os


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(path: str, title: str) -> str:
    if not os.path.exists(path):
        return f"*(missing {path})*\n"
    rows = json.load(open(path))
    out = [f"### {title}", "",
           "| arch | shape | strat | mb | t_comp (s) | t_mem (s) | t_coll (s) "
           "| bottleneck | MODEL/HLO flops | mfu_bound | mem/dev (GiB) |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | "
                       f"{r.get('error','')[:60]} | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} "
            f"| {r.get('microbatches',1)} "
            f"| {r['t_compute']:.3f} | {r['t_memory']:.3f} "
            f"| {r['t_collective']:.3f} | **{r['bottleneck']}** "
            f"| {r['useful_flops_ratio']:.2f} | {r['mfu_bound']:.3f} "
            f"| {fmt_bytes(r['bytes_per_device'])} |")
    out.append("")
    return "\n".join(out)


def collective_table(path: str, title: str) -> str:
    if not os.path.exists(path):
        return ""
    rows = json.load(open(path))
    out = [f"### {title}: collective schedule (per-device send GB / counts)",
           "",
           "| arch | shape | all-reduce | all-gather | reduce-scatter "
           "| all-to-all | permute |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            continue
        c = r.get("collectives", {})
        n = r.get("collective_counts", {})

        def cell(op):
            if op not in c:
                return "-"
            return f"{c[op]/1e9:.2f} ({n.get(op, 0)})"
        out.append(f"| {r['arch']} | {r['shape']} | {cell('all-reduce')} "
                   f"| {cell('all-gather')} | {cell('reduce-scatter')} "
                   f"| {cell('all-to-all')} | {cell('collective-permute')} |")
    out.append("")
    return "\n".join(out)


def main():
    print(roofline_table("results/dryrun_single_pod.json",
                         "Single-pod 16x16 (256 chips) — baseline roofline"))
    print(roofline_table("results/dryrun_multi_pod.json",
                         "Multi-pod 2x16x16 (512 chips)"))
    print(collective_table("results/dryrun_single_pod.json",
                           "Single-pod 16x16"))


if __name__ == "__main__":
    main()
