"""System behaviour tests mirroring the paper's claims at reduced scale.

Each test asserts a *shape* from the paper's evaluation (§IV): queuing
breakdown structure, vNode semantics, dedup, scan cost — the quantitative
validation lives in benchmarks/ (EXPERIMENTS.md)."""
import statistics
import threading
import time

import pytest

from repro.core import VirtualClusterFramework


@pytest.fixture(scope="module")
def burst_rig():
    """One shared burst: 8 tenants x 25 units on 20 nodes."""
    fw = VirtualClusterFramework(num_nodes=20, scan_interval=0.0,
                                 heartbeat_interval=3600)
    fw.start()
    planes = [fw.add_tenant(f"t{i}") for i in range(8)]

    def submit(plane):
        for j in range(25):
            fw.submit(plane, fw.make_unit(f"u{j:03d}", "default", chips=0))

    threads = [threading.Thread(target=submit, args=(p,)) for p in planes]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for p in planes:
        fw.wait_all_ready(p, "default", 25, timeout=120)
    yield fw, planes
    fw.stop()


def test_all_units_reach_ready(burst_rig):
    fw, planes = burst_rig
    for p in planes:
        units = p.api.list("WorkUnit", "default")
        assert len(units) == 25
        assert all(u.status.phase == "Ready" for u in units)


def test_latency_breakdown_has_paper_structure(burst_rig):
    """Paper Fig.8: queue phases dominate sync-processing phases; the
    downward/upward *processing* times are trivial."""
    fw, planes = burst_rig
    tls = [tl for tl in fw.syncer.metrics.timelines.values() if tl.complete]
    assert len(tls) == 200
    means = {}
    for phase in ("DWS-Queue", "DWS-Process", "Super-Sched", "UWS-Queue",
                  "UWS-Process"):
        means[phase] = statistics.mean(tl.phases()[phase] for tl in tls)
    assert means["DWS-Process"] < max(means["DWS-Queue"],
                                      means["Super-Sched"])
    assert means["UWS-Process"] < 0.5


def test_every_unit_bound_to_virtual_node(burst_rig):
    """vNode semantics: each Ready unit's node maps 1:1 to a physical node
    that exists as a VirtualNode object in the tenant plane."""
    fw, planes = burst_rig
    for p in planes:
        vnodes = {v.metadata.name for v in p.api.list("VirtualNode")}
        for u in p.api.list("WorkUnit", "default"):
            assert u.status.node in vnodes
        for v in p.api.list("VirtualNode"):
            assert v.physical_node == v.metadata.name  # 1:1 mapping


def test_dedup_reduces_sync_work(burst_rig):
    """Paper §III-C: "the client-go worker queue has the capability of
    deduplicating the incoming requests". Back-to-back updates of the same
    key land while the first add is still queued/processing, so the second
    is absorbed. (Label-only updates: no spec change reaches the super
    cluster, so this is pure sync-queue traffic.)"""
    fw, planes = burst_rig
    q = fw.syncer.down_queue

    def churn(plane):
        for j in range(25):
            for rev in ("a", "b"):
                u = plane.api.get("WorkUnit", "default", f"u{j:03d}")
                u.metadata.labels["rev"] = rev
                plane.api.update(u)

    threads = [threading.Thread(target=churn, args=(p,)) for p in planes]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline and q.deduped == 0:
        time.sleep(0.01)
    assert q.deduped > 0          # duplicate sync requests were absorbed
    assert q.added > q.deduped


def test_periodic_scan_is_cheap_and_idempotent(burst_rig):
    fw, planes = burst_rig
    t0 = time.monotonic()
    fixes = fw.syncer.scan_once()
    dur = time.monotonic() - t0
    assert dur < 5.0              # paper: <2 s for 10k pods (we have 200)
    assert fixes == 0             # steady state: nothing to remediate
