"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf]. 64 heads of head_size 64 (d_model 4096)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536,
    layer_pattern="r", rwkv_head_size=64,
    use_rope=False,
)
