"""Serving data plane: fused-admission engine exactness vs the models-API
reference loop (ragged attention batches + recurrent exact-length buckets),
admission under full slots with slot reuse, thread-safe batcher submits with
TTFT stamps, WRR slot-scheduler fairness vs the FIFO baseline, greedy-flood
starvation regression, the control→data plane bridge (engine replicas as
WorkUnits, per-tenant metrics), agent cleanup of deleted units, and the
autoscaler's fourth (engine-replica) actuator."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (APIServer, Autoscaler, CooperativeExecutor,
                        ScalingPolicy, Syncer, TenantControlPlane,
                        VirtualClusterFramework)
from repro.core.agent import MockProvider
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serving import (ContinuousBatcher, GenerationEngine, Request,
                           ServingFleet, SlotScheduler, generate)

F32 = jnp.float32
MAX_LEN = 48


def wait_for(cond, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.005)
    return False


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("qwen2-7b"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _ref_generate(cfg, params, prompt, max_new, max_len=MAX_LEN):
    """Independent oracle: the hand-rolled per-request prefill+decode loop
    over the raw models API (the seed ``generate()`` path)."""
    cache = init_cache(cfg, 1, max_len, enc_len=max_len)
    logits, cache, lengths = prefill(
        params, cfg, jnp.asarray(np.asarray(prompt)[None], jnp.int32),
        cache, compute_dtype=F32)
    toks = [int(jnp.argmax(logits[0, -1, :cfg.vocab]))]
    lengths = lengths + 1
    for _ in range(max_new - 1):
        logits, cache, lengths = decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            lengths, compute_dtype=F32)
        toks.append(int(jnp.argmax(logits[0, 0, :cfg.vocab])))
    return toks


# ------------------------------------------------------------ slot scheduler

def _req(uid, tenant="t"):
    return Request(uid, np.zeros(4, np.int32), 4, tenant=tenant)


def test_slot_scheduler_wrr_interleaves_tenants():
    s = SlotScheduler()
    for i in range(6):
        s.submit("greedy", _req(i, "greedy"))
    s.submit("steady", _req(100, "steady"))
    s.submit("steady", _req(101, "steady"))
    # WRR with equal weights alternates tenants: the steady tenant gets a
    # slot in the first dispatch pair despite 6 queued greedy requests
    first_pair = [r.tenant for r in s.take(2)]
    assert "steady" in first_pair
    rest = s.take(10)
    assert len(rest) == 6
    assert s.pending() == 0
    assert s.dispatched == 8


def test_slot_scheduler_fifo_baseline_starves():
    s = SlotScheduler(fair=False)
    for i in range(6):
        s.submit("greedy", _req(i, "greedy"))
    s.submit("steady", _req(100, "steady"))
    order = [r.tenant for r in s.take(7)]
    assert order.index("steady") == 6     # strictly behind the flood


def test_slot_scheduler_weights_and_drain():
    s = SlotScheduler()
    s.register_tenant("a", weight=2)
    s.register_tenant("b", weight=1)
    for i in range(4):
        s.submit("a", _req(i, "a"))
        s.submit("b", _req(10 + i, "b"))
    got = [r.tenant for r in s.take(3)]
    assert got.count("a") == 2 and got.count("b") == 1   # 2:1 credit split
    assert s.set_weight("b", 3) is True
    assert s.set_weight("b", 3) is False                 # no-op
    drained = s.drain_tenant("a")
    assert len(drained) == 2 and all(r.tenant == "a" for r in drained)
    assert s.pending_by_tenant() == {"b": 3}
    stats = s.tenant_wait_stats()
    assert set(stats) == {"a", "b"} and stats["a"][0] == 2
    assert s.tenant_wait_stats() == {}                   # drained


# ------------------------------------------------------------ engine exactness

def test_ragged_batch_exactness_vs_reference(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 3, 12)]
    eng = GenerationEngine(cfg, params, slots=4, max_len=MAX_LEN,
                           compute_dtype=F32)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    eng.admit_many(reqs)
    while eng.active_slots():
        eng.step()
    for r, p in zip(reqs, prompts):
        assert r.tokens == _ref_generate(cfg, params, p, 6)
    # fused admission: buckets {8, 16} -> 2 jitted calls, zero full-cache
    # rescatter copies, one host sync per admit call / decode step
    assert eng.admit_calls == 2
    assert eng.full_cache_copies == 0
    assert eng.host_syncs == eng.admit_calls + eng.steps


def test_recurrent_pattern_exact_length_buckets():
    """Patterns with recurrent layers fold pad tokens into their state, so
    the engine buckets them by exact prompt length — outputs must still
    match the per-request reference exactly."""
    cfg = reduced(get_config("rwkv6-7b"), n_layers=2)
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 5)]
    eng = GenerationEngine(cfg, params, slots=3, max_len=MAX_LEN,
                           compute_dtype=F32)
    assert eng._exact_buckets
    reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
    eng.admit_many(reqs)
    while eng.active_slots():
        eng.step()
    for r, p in zip(reqs, prompts):
        assert r.tokens == _ref_generate(cfg, params, p, 4)
    assert eng.admit_calls == 2       # lengths {5, 5} and {9}


def test_generate_routes_through_engine(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    batch = np.stack([rng.integers(0, cfg.vocab, 8).astype(np.int32)
                      for _ in range(3)])
    out = generate(cfg, params, batch, max_new_tokens=5, max_len=MAX_LEN,
                   compute_dtype=F32)
    assert out.shape == (3, 5)
    for i in range(3):
        assert list(out[i]) == _ref_generate(cfg, params, batch[i], 5)
    with pytest.raises(ValueError):
        generate(cfg, params, batch, max_new_tokens=MAX_LEN,
                 max_len=MAX_LEN, compute_dtype=F32)


# ------------------------------------------------- admission under full slots

def test_admission_under_full_slots_and_slot_reuse(model):
    cfg, params = model
    eng = GenerationEngine(cfg, params, slots=2, max_len=MAX_LEN,
                           compute_dtype=F32)
    batcher = ContinuousBatcher(eng)
    rng = np.random.default_rng(3)
    uids = [batcher.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=4)
            for _ in range(6)]
    assert len(set(uids)) == 6
    # only 2 slots: the first pump admits 2 and leaves 4 queued
    batcher.pump()
    assert eng.active_slots() == 2
    assert batcher.scheduler.pending() == 4
    batcher.run_until_drained()
    assert len(batcher.completed) == 6
    assert eng.admitted == 6
    assert eng.full_cache_copies == 0
    for uid in uids:
        req = batcher.completed[uid]
        assert req.done and len(req.tokens) == 4
        # exactness survives slot reuse
        assert req.tokens == _ref_generate(cfg, params, req.prompt, 4)


def test_engine_rejects_overlong_prompt(model):
    cfg, params = model
    eng = GenerationEngine(cfg, params, slots=1, max_len=16,
                           compute_dtype=F32)
    with pytest.raises(ValueError):
        eng.admit_many([Request(0, np.zeros(16, np.int32), 4)])
    batcher = ContinuousBatcher(eng)
    with pytest.raises(ValueError):
        batcher.submit(np.zeros(16, np.int32))


def test_batcher_thread_safe_submit_with_ttft(model):
    cfg, params = model
    eng = GenerationEngine(cfg, params, slots=2, max_len=MAX_LEN,
                           compute_dtype=F32)
    batcher = ContinuousBatcher(eng)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(12)]
    uids, ulock = [], threading.Lock()

    def submit(chunk):
        for p in chunk:
            uid = batcher.submit(p, max_new_tokens=3)
            with ulock:
                uids.append(uid)

    threads = [threading.Thread(target=submit, args=(prompts[i::4],))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # concurrent submits must never reuse a uid (the seed batcher bumped
    # _uid without a lock)
    assert sorted(uids) == list(range(1, 13))
    batcher.run_until_drained()
    assert len(batcher.completed) == 12
    for req in batcher.completed.values():
        assert req.first_token_at >= req.submitted_at
        assert req.finished_at >= req.first_token_at
        assert req.first_token_at > 0.0


# ------------------------------------------------- starvation regression

def _flood_ttfts(cfg, params, fair):
    """Greedy tenant floods 10 requests ahead of 2 steady ones; return the
    steady tenant's worst TTFT under the given scheduling mode."""
    eng = GenerationEngine(cfg, params, slots=2, max_len=MAX_LEN,
                           compute_dtype=F32)
    batcher = ContinuousBatcher(eng, scheduler=SlotScheduler(fair=fair))
    rng = np.random.default_rng(5)
    steady = []
    for _ in range(10):
        batcher.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=6,
                       tenant="greedy")
    for _ in range(2):
        steady.append(batcher.submit(rng.integers(0, cfg.vocab, 8),
                                     max_new_tokens=6, tenant="steady"))
    batcher.run_until_drained()
    return max(batcher.completed[uid].first_token_at
               - batcher.completed[uid].submitted_at for uid in steady)


def test_wrr_bounds_steady_tenant_ttft_under_flood(model):
    """The fig11 data-plane analog: under a greedy flood, WRR admission
    dispatches the steady tenant ahead of the backlog while FIFO serves it
    dead last — its TTFT must be strictly better under WRR."""
    cfg, params = model
    _flood_ttfts(cfg, params, fair=True)   # warm the XLA compile cache so
    fair = _flood_ttfts(cfg, params, fair=True)   # neither timed run pays it
    fifo = _flood_ttfts(cfg, params, fair=False)
    assert fair < fifo


# ------------------------------------------------- control→data plane bridge

def test_fleet_bridge_replicas_metrics_and_scaledown(model):
    cfg, params = model
    fleet = ServingFleet(
        lambda: GenerationEngine(cfg, params, slots=2, max_len=MAX_LEN,
                                 compute_dtype=F32),
        replicas=2, scan_interval=0.05)
    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=3600)
    fleet.attach(fw)
    with fw:
        plane_a = fw.add_tenant("alpha", weight=2)
        fleet.register_tenant(plane_a)
        fleet.register_tenant("beta")
        with pytest.raises(PermissionError):
            fleet.submit("ghost", np.zeros(4, np.int32))
        # replicas ride the control plane: engine-0/1 WorkUnits scheduled
        # onto nodes, provider spawns the live engines
        assert wait_for(lambda: fleet.live_replicas() == 2, timeout=20)
        assert wait_for(lambda: all(
            u.status.phase == "Ready"
            for u in fw.super_api.list("WorkUnit", "vc-serving")), timeout=20)
        units = fw.super_api.list("WorkUnit", "vc-serving")
        assert sorted(u.metadata.name for u in units) == \
            ["engine-0", "engine-1"]
        rng = np.random.default_rng(6)
        for _ in range(4):
            fleet.submit("alpha", rng.integers(0, cfg.vocab, 8),
                         max_new_tokens=4)
        for _ in range(2):
            fleet.submit("beta", rng.integers(0, cfg.vocab, 8),
                         max_new_tokens=4)
        done = fleet.wait_completed(6, timeout=60)
        assert len(done) == 6
        assert all(r.done and len(r.tokens) == 4 for r in done.values())
        # per-tenant serving metrics landed in the shared registry
        snap = fw.metrics.snapshot()
        assert snap["summaries"]["serving_ttft_seconds{tenant=alpha}"][
            "count"] == 4
        assert snap["counters"]["serving_tokens_total{tenant=beta}"] == 8.0
        assert snap["counters"]["serving_requests_total{tenant=alpha}"] == 4.0
        assert snap["gauges"]["serving_live_replicas"] == 2.0
        assert snap["gauges"]["serving_pending_requests"] == 0.0
        # scale down: surplus unit deleted, its replica drained + retired
        fleet.resize(1)
        assert wait_for(lambda: fleet.live_replicas() == 1, timeout=20)
        assert wait_for(lambda: len(
            fw.super_api.list("WorkUnit", "vc-serving")) == 1, timeout=20)
        assert fleet.retired == 1


def test_agent_stops_deleted_units():
    """A DELETED WorkUnit reaches the node agent, which releases the
    provider's resources (and forgets the key so a recreate can run)."""
    stopped = []

    class RecordingProvider(MockProvider):
        def stop(self, unit):
            stopped.append(unit.metadata.key)

    fw = VirtualClusterFramework(
        num_nodes=1, scan_interval=0.0, heartbeat_interval=3600,
        provider_factory=lambda name: RecordingProvider())
    from repro.core import WorkUnit
    with fw:
        unit = WorkUnit()
        unit.metadata.name = "w0"
        unit.metadata.namespace = "default"
        fw.super_api.create(unit)
        agent = next(iter(fw.agents.values()))
        assert wait_for(lambda: "default/w0" in agent._running_units)
        fw.super_api.delete("WorkUnit", "default", "w0")
        assert wait_for(lambda: stopped == ["default/w0"])
        assert "default/w0" not in agent._running_units


# ------------------------------------------------- fourth actuator

class _FakeFleet:
    """Stands in for ServingFleet in actuator unit tests."""

    def __init__(self, replicas=1, pending=0):
        self.desired_replicas = replicas
        self.pending_n = pending
        self.resizes = []
        self.scheduler = self

    def pending(self):
        return self.pending_n

    def live_replicas(self):
        return self.desired_replicas

    def resize(self, n):
        self.resizes.append(n)
        self.desired_replicas = n
        return n


def _scaler_rig():
    ex = CooperativeExecutor(pool_size=2, name="srv-as-test")
    api = APIServer("super")
    syncer = Syncer(api, downward_workers=2, upward_workers=2,
                    scan_interval=0.0, shards=1, executor=ex)
    syncer.register_tenant(TenantControlPlane("t0"), "uid-0")
    syncer.start()
    policy = ScalingPolicy(min_engine_replicas=1, max_engine_replicas=4,
                           engine_up_pending=2.0, engine_down_pending=0.25,
                           engine_up_ttft_s=10.0, hysteresis=2,
                           up_cooldown_s=0.1, down_cooldown_s=0.2,
                           window_s=1.5)
    return ex, syncer, Autoscaler(syncer, None, policy=policy,
                                  interval=3600)


def test_engine_actuator_scales_fleet_up_and_down():
    ex, syncer, scaler = _scaler_rig()
    fleet = _FakeFleet(replicas=1, pending=10)
    try:
        scaler.set_engine_fleet(fleet)
        # backlog of 10 pending on 1 replica breaches for 2 ticks -> x2
        scaler.tick(now=0.0)
        scaler.tick(now=0.05)
        assert fleet.resizes == [2]
        assert scaler.scale_events()[-1]["actuator"] == "engine_replicas"
        assert scaler.scale_events()[-1]["direction"] == "up"
        # drain: pending drops to zero; after the down-cooldown the fleet
        # halves back toward the floor
        fleet.pending_n = 0
        t = 10.0
        while fleet.desired_replicas > 1 and t < 60.0:
            scaler.tick(now=t)
            t += 0.3
        assert fleet.desired_replicas == 1
        assert scaler.scale_events()[-1]["direction"] == "down"
    finally:
        syncer.stop()
        ex.shutdown()


def test_engine_actuator_absent_fleet_is_noop():
    ex, syncer, scaler = _scaler_rig()
    try:
        assert scaler.engine_fleet is None
        scaler.tick(now=0.0)
        scaler.tick(now=0.1)
        assert all(e["actuator"] != "engine_replicas"
                   for e in scaler.scale_events())
        assert scaler.state()["targets"]["engine_replicas"] is None
    finally:
        syncer.stop()
        ex.shutdown()
