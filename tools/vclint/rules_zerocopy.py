"""VCL003/VCL007: misuse of zero-copy (``copy=False``) store references.

Function-local taint tracking: a variable is tainted when bound from a
call with a literal ``copy=False`` keyword (``list`` / ``watch`` /
``list_and_watch`` / ``list_paged`` / ``list_all_pages`` / ``get``
store APIs) or from ``.peek()``. Taint propagates through assignment,
tuple unpacking, for-loop targets over tainted iterables, and
subscript/attribute reads; it is cleansed by an explicit copy
(``deepcopy_obj`` / ``copy.deepcopy`` / ``list()`` / ``dict()`` /
``sorted()``). VCL003 flags: attribute/item assignment whose target
roots at a tainted name, and mutating-method calls (``append`` /
``update`` / ``sort`` / ...) on tainted receivers.

VCL007 guards the observability hook boundary: audit records and usage
samples outlive the request that produced them (they sit in retention
rings scraped later by ``/audit`` and ``/usage``), so a hook call must
only be handed scalars. Passing a tainted object itself — or one of its
mutable container fields (``metadata``, ``annotations``, ``status``,
...) — into ``record`` / ``record_from`` / ``add`` / ``add_many``
retains a live reference to shared store state past the hook boundary:
a later writer mutates what the scrape returns. Extract the scalar
(``obj.metadata.name``, ``float(n)``) at the call site instead.
"""
from __future__ import annotations

import ast
from typing import List, Set

from .engine import Finding, Rule
from .model import Project, iter_functions, root_name, walk_in_scope

TAINT_SOURCES = {"list", "watch", "list_and_watch", "list_page",
                 "list_paged", "list_all_pages", "get"}
PEEK_SOURCES = {"peek"}
MUTATORS = {"append", "extend", "insert", "remove", "pop", "clear", "sort",
            "reverse", "update", "setdefault", "popitem", "add", "discard",
            "set_condition", "__setitem__"}
CLEANSERS = {"deepcopy_obj", "deepcopy", "list", "dict", "sorted", "tuple",
             "set", "frozenset", "copy_obj"}
# VCL007: observability hooks whose arguments are RETAINED (audit rings,
# usage series) — handing them a live zero-copy ref outlives the read
SINK_METHODS = {"record", "record_from", "add_many"}
# `.add(...)` doubles as set.add(); only treat it as a sink when the
# receiver looks like a meter/audit handle, not a collection
SINK_ADD_RECEIVERS = {"meter", "audit", "m", "um", "au", "_meter", "_audit"}
# container-valued object fields: retaining one of these is retaining
# shared mutable state even though the chain "looks" field-scoped
MUTABLE_FIELDS = {"metadata", "annotations", "labels", "status", "spec",
                  "conditions", "endpoints", "payload", "attrs", "data"}


def _has_copy_false(call: ast.Call) -> bool:
    return any(kw.arg == "copy" and isinstance(kw.value, ast.Constant)
               and kw.value.value is False for kw in call.keywords)


def _is_taint_source(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute):
        if f.attr in PEEK_SOURCES:
            return True
        if f.attr in TAINT_SOURCES and _has_copy_false(call):
            return True
    return False


def _is_cleanser(call: ast.Call) -> bool:
    f = call.func
    name = f.id if isinstance(f, ast.Name) else (
        f.attr if isinstance(f, ast.Attribute) else "")
    return name in CLEANSERS


class ZeroCopyMutationRule(Rule):
    id = "VCL003"
    description = "mutation of copy=False (zero-copy) store references"

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for mod in project.modules:
            for qualname, _ci, fn in iter_functions(mod):
                findings.extend(self._check_fn(mod.relpath, qualname, fn))
        return findings

    def _check_fn(self, relpath: str, qualname: str,
                  fn: ast.FunctionDef) -> List[Finding]:
        tainted: Set[str] = set()
        findings: List[Finding] = []

        def expr_tainted(expr: ast.expr) -> bool:
            if isinstance(expr, ast.Call):
                if _is_taint_source(expr):
                    return True
                if _is_cleanser(expr):
                    return False
                return False
            if isinstance(expr, (ast.Attribute, ast.Subscript, ast.Name,
                                 ast.Starred)):
                r = root_name(expr)
                return r is not None and r in tainted
            if isinstance(expr, ast.IfExp):
                return expr_tainted(expr.body) or expr_tainted(expr.orelse)
            return False

        def bind(target: ast.expr, value_tainted: bool) -> None:
            if isinstance(target, ast.Name):
                if value_tainted:
                    tainted.add(target.id)
                else:
                    tainted.discard(target.id)
            elif isinstance(target, (ast.Tuple, ast.List)):
                for elt in target.elts:
                    bind(elt, value_tainted)
            elif isinstance(target, ast.Starred):
                bind(target.value, value_tainted)

        # statement-ordered walk (taint is flow-insensitive within loops but
        # assignment order matters for the common straight-line cases)
        for node in walk_in_scope(fn):
            if isinstance(node, ast.Assign):
                vt = expr_tainted(node.value)
                for tgt in node.targets:
                    if isinstance(tgt, (ast.Name, ast.Tuple, ast.List,
                                        ast.Starred)):
                        bind(tgt, vt)
                    elif isinstance(tgt, (ast.Attribute, ast.Subscript)):
                        r = root_name(tgt)
                        if r in tainted:
                            findings.append(self._finding(
                                relpath, qualname, node.lineno,
                                f"assign:{r}",
                                f"assignment into zero-copy ref '{r}'"))
            elif isinstance(node, ast.AugAssign):
                tgt = node.target
                if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                    r = root_name(tgt)
                    if r in tainted:
                        findings.append(self._finding(
                            relpath, qualname, node.lineno,
                            f"augassign:{r}",
                            f"augmented assignment into zero-copy ref "
                            f"'{r}'"))
            elif isinstance(node, ast.For):
                bind(node.target, expr_tainted(node.iter))
            elif isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr in MUTATORS:
                    r = root_name(f.value)
                    if r is not None and r in tainted:
                        findings.append(self._finding(
                            relpath, qualname, node.lineno,
                            f"mutate:{r}.{f.attr}",
                            f"mutating call .{f.attr}() on zero-copy "
                            f"ref '{r}'"))
        return findings

    def _finding(self, relpath: str, qualname: str, line: int,
                 detail: str, what: str) -> Finding:
        return Finding(
            self.id, relpath, line, qualname, detail=detail,
            message=(f"{what} — copy=False returns shared READ-ONLY store "
                     f"state; deepcopy_obj() it before mutating"))


class ZeroCopyRetentionRule(Rule):
    id = "VCL007"
    description = ("zero-copy reference retained past an audit/metering "
                   "hook boundary")

    def check(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for mod in project.modules:
            for qualname, _ci, fn in iter_functions(mod):
                findings.extend(self._check_fn(mod.relpath, qualname, fn))
        return findings

    def _check_fn(self, relpath: str, qualname: str,
                  fn: ast.FunctionDef) -> List[Finding]:
        tainted: Set[str] = set()
        findings: List[Finding] = []

        def expr_tainted(expr: ast.expr) -> bool:
            if isinstance(expr, ast.Call):
                return _is_taint_source(expr)
            if isinstance(expr, (ast.Attribute, ast.Subscript, ast.Name,
                                 ast.Starred)):
                r = root_name(expr)
                return r is not None and r in tainted
            if isinstance(expr, ast.IfExp):
                return expr_tainted(expr.body) or expr_tainted(expr.orelse)
            return False

        def bind(target: ast.expr, value_tainted: bool) -> None:
            if isinstance(target, ast.Name):
                if value_tainted:
                    tainted.add(target.id)
                else:
                    tainted.discard(target.id)
            elif isinstance(target, (ast.Tuple, ast.List)):
                for elt in target.elts:
                    bind(elt, value_tainted)
            elif isinstance(target, ast.Starred):
                bind(target.value, value_tainted)

        def is_sink(call: ast.Call) -> bool:
            f = call.func
            if not isinstance(f, ast.Attribute):
                return False
            if f.attr in SINK_METHODS:
                return True
            if f.attr == "add":
                recv = f.value
                leaf = (recv.id if isinstance(recv, ast.Name)
                        else recv.attr if isinstance(recv, ast.Attribute)
                        else "")
                return leaf in SINK_ADD_RECEIVERS
            return False

        def retained_ref(arg: ast.expr) -> str:
            """Return a description if ``arg`` hands the sink a live
            mutable ref rooted in a tainted name, else ''."""
            if isinstance(arg, ast.Starred):
                return retained_ref(arg.value)
            if isinstance(arg, ast.Name):
                return arg.id if arg.id in tainted else ""
            if isinstance(arg, ast.Subscript):
                # objs[0] hands over the whole object, not a field of it
                r = root_name(arg)
                return r if r is not None and r in tainted else ""
            if isinstance(arg, ast.Attribute):
                r = root_name(arg)
                if r is not None and r in tainted \
                        and arg.attr in MUTABLE_FIELDS:
                    return f"{r}...{arg.attr}"
            return ""

        for node in walk_in_scope(fn):
            if isinstance(node, ast.Assign):
                vt = expr_tainted(node.value)
                for tgt in node.targets:
                    if isinstance(tgt, (ast.Name, ast.Tuple, ast.List,
                                        ast.Starred)):
                        bind(tgt, vt)
            elif isinstance(node, ast.For):
                bind(node.target, expr_tainted(node.iter))
            elif isinstance(node, ast.Call) and is_sink(node):
                args = list(node.args) + [kw.value for kw in node.keywords]
                for arg in args:
                    ref = retained_ref(arg)
                    if ref:
                        fname = node.func.attr   # type: ignore[attr-defined]
                        findings.append(Finding(
                            self.id, relpath, node.lineno, qualname,
                            detail=f"retain:{fname}:{ref}",
                            message=(
                                f"zero-copy ref '{ref}' passed to "
                                f".{fname}() — audit/usage hooks retain "
                                f"their arguments past the request; pass "
                                f"extracted scalars, not live store "
                                f"objects")))
        return findings
