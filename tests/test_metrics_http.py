"""The metrics/traces HTTP endpoint under concurrency: parallel scrapes
of every route must each see a consistent JSON document, and a framework
shutdown racing in-flight scrapes must neither hang nor corrupt — late
requests simply fail with a connection error."""
import json
import threading
import time
import urllib.error
import urllib.request

from repro.core.cluster import VirtualClusterFramework

ROUTES = ("/metrics", "/healthz", "/traces", "/traces/chrome")


def _get(port, route, timeout=5):
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{route}", timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_concurrent_scrapes_see_consistent_documents():
    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=0.5, tracing=True)
    with fw:
        plane = fw.add_tenant("acme")
        fw.submit(plane, fw.make_unit("probe", chips=1))
        port = fw.serve_metrics(port=0)
        errors = []

        def scrape(worker):
            try:
                for i in range(20):
                    route = ROUTES[(worker + i) % len(ROUTES)]
                    code, doc = _get(port, route)
                    assert code in (200, 503), (route, code)
                    if route == "/metrics":
                        assert set(doc) == {"counters", "summaries",
                                            "gauges", "histograms"}
                    elif route == "/healthz":
                        assert set(doc) >= {"controllers", "slo"}
                    elif route == "/traces":
                        assert doc["enabled"] is True
                        for s in doc["spans"]:
                            assert "trace_id" in s and "name" in s
                    else:
                        assert "traceEvents" in doc
            except Exception as e:
                errors.append(e)

        threads = [threading.Thread(target=scrape, args=(w,))
                   for w in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads)
        assert not errors


def test_shutdown_races_inflight_scrapes_without_hanging():
    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=0.5, tracing=True)
    fw.start()
    port = fw.serve_metrics(port=0)
    stop = threading.Event()
    hard_errors = []

    def scrape():
        while not stop.is_set():
            try:
                _get(port, "/metrics", timeout=2)
            except (OSError, urllib.error.URLError):
                # server torn down mid-request/after: expected outcome
                return
            except Exception as e:          # pragma: no cover - fail path
                hard_errors.append(e)
                return

    threads = [threading.Thread(target=scrape) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.3)                         # let scrapes get in flight
    fw.stop()                               # shut down under load
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert not any(t.is_alive() for t in threads)
    assert not hard_errors
    # the port is actually closed: a fresh request must fail fast
    try:
        _get(port, "/metrics", timeout=2)
    except (OSError, urllib.error.URLError):
        pass
    else:
        raise AssertionError("server still answering after stop()")


def test_serve_metrics_is_idempotent_and_restartable():
    fw = VirtualClusterFramework(num_nodes=2, scan_interval=0.0,
                                 heartbeat_interval=0.5)
    with fw:
        port = fw.serve_metrics(port=0)
        assert fw.serve_metrics(port=0) == port   # second call: same server
        code, _ = _get(port, "/metrics")
        assert code == 200
