"""Ragged grouped GEMM (dropless-MoE expert matmul) as a Pallas TPU kernel.

The megablocks insight adapted to the MXU: pad each expert's token group to
a multiple of the row-block (the caller aligns the dispatch), precompute one
expert id per row block, and let the kernel pick its expert's weight tile
through the scalar-prefetch index map — every grid cell is then a dense
[bm, D] x [D, bf] MXU matmul with zero divergence and no gather/scatter in
the hot loop.

Grid (num_row_blocks, num_col_blocks); block_expert (scalar-prefetched,
SMEM) drives the W index map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gg_kernel(block_expert_ref, x_ref, w_ref, o_ref):
    x = x_ref[...]
    w = w_ref[0]
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def grouped_gemm_pallas(x: jnp.ndarray, block_expert: jnp.ndarray,
                        W: jnp.ndarray, *, block_m: int = 128,
                        block_f: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """x: [T, D] block-aligned sorted tokens; block_expert: [T // block_m]
    expert id per row block; W: [E, D, F] -> [T, F]."""
    T, D = x.shape
    E, _, F = W.shape
    assert T % block_m == 0, "caller must pad groups to block_m multiples"
    bf = min(block_f, F)
    nf = -(-F // bf)
    Fp = nf * bf
    Wp = jnp.pad(W, ((0, 0), (0, 0), (0, Fp - F))) if Fp != F else W
    nm = T // block_m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nf),
        in_specs=[
            pl.BlockSpec((block_m, D), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, D, bf), lambda i, j, be: (be[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, bf), lambda i, j, be: (i, j)),
    )
    out = pl.pallas_call(
        _gg_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, Fp), x.dtype),
        interpret=interpret,
    )(block_expert.astype(jnp.int32), x, Wp)
    return out[:, :F]
