"""Fault tolerance: node failure mid-stream + checkpoint restart.

A tenant streams training WorkUnits; we kill the node they run on; the
scheduler re-binds to a healthy node and the provider resumes from the last
checkpoint — no tenant-visible API change (the unit just restarts, paper
vNode semantics preserved).

    PYTHONPATH=src python examples/elastic_failover.py
"""
import shutil

import jax

from repro.ckpt import CheckpointManager
from repro.configs import get_config, reduced
from repro.core import CallableProvider, VirtualClusterFramework
from repro.data import DataConfig, SyntheticTokens
from repro.models import init_params
from repro.models.config import ShapeConfig
from repro.training import OptimizerConfig, make_opt_state, make_train_step


def main():
    cfg = reduced(get_config("yi-9b"), d_model=64, n_layers=2, vocab=512)
    shape = ShapeConfig("demo", 64, 4, "train")
    step_fn = jax.jit(make_train_step(cfg, OptimizerConfig(peak_lr=1e-3)))
    data = SyntheticTokens(cfg, shape, DataConfig(seed=0))
    # fresh demo state: stale checkpoints from a previous invocation would
    # make every unit resume past its final step (empty train loop)
    shutil.rmtree("/tmp/vc-failover-demo", ignore_errors=True)
    mgr = CheckpointManager("/tmp/vc-failover-demo", keep=2)

    def make_provider(node_name):
        """Each node restores from the latest checkpoint before running —
        exactly what a fresh host does after taking over a failed job."""
        def run_unit(unit):
            params = init_params(jax.random.PRNGKey(0), cfg)
            opt = make_opt_state(params)
            start = 0
            if mgr.latest_step() is not None:
                (params, opt), start = mgr.restore((params, opt))
            base = unit.spec.payload["base_step"]
            begin = max(base, start)
            loss = None
            for s in range(begin, base + 5):
                params, opt, metrics = step_fn(params, opt, data.batch_at(s))
                loss = float(metrics["loss"])
            mgr.save(base + 5, (params, opt), block=True)
            return {"node": node_name, "loss": loss, "resumed_from": start}
        return CallableProvider(run_unit)

    fw = VirtualClusterFramework(num_nodes=3, scan_interval=0.0,
                                 heartbeat_interval=3600,
                                 provider_factory=make_provider)
    with fw:
        tenant = fw.add_tenant("resilient-team")
        # unit 0 runs normally
        fw.submit(tenant, fw.make_unit("u0", "jobs", chips=1,
                                       payload={"base_step": 0}))
        u0 = fw.wait_ready(tenant, "jobs", "u0", timeout=120)
        node0 = u0.status.node
        print(f"u0 ran on {node0}, checkpoints: {mgr.all_steps()}")

        # kill that node, then submit the next unit
        fw.super_api.update_status(
            "Node", "", node0, lambda n: setattr(n.status, "phase",
                                                 "NotReady"))
        fw.scheduler.node_failed(node0)
        print(f"killed {node0}")
        fw.submit(tenant, fw.make_unit("u1", "jobs", chips=1,
                                       payload={"base_step": 5}))
        u1 = fw.wait_ready(tenant, "jobs", "u1", timeout=120)
        agent = fw.agents[u1.status.node]
        result = list(agent.provider.results.values())[-1]
        print(f"u1 rescheduled to {u1.status.node} "
              f"(resumed from checkpoint step {result['resumed_from']}, "
              f"loss {result['loss']:.3f})")
        assert u1.status.node != node0
        print(f"checkpoints after failover: {mgr.all_steps()}")
    print("done")


if __name__ == "__main__":
    main()
