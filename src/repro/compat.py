"""JAX version-compatibility shims (pinned environment: jax 0.4.37).

Two API seams moved between jax releases:

- ``AbstractMesh``: newer code writes ``AbstractMesh(shape, axis_names)``;
  0.4.37 takes a single ``shape_tuple`` of ``(axis_name, size)`` pairs.
  :func:`abstract_mesh` accepts the readable two-argument form and builds
  whichever the installed jax understands.
- ``shard_map``: newer code calls ``jax.shard_map(..., axis_names=...,
  check_vma=...)``; 0.4.37 only has ``jax.experimental.shard_map.shard_map``
  with ``auto=...`` (the complement of ``axis_names``) and ``check_rep=...``.
  :func:`shard_map` presents the new keyword surface on either version.

All sharding/model code should import these from here rather than touching
``jax.shard_map`` / ``AbstractMesh`` directly.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Device-free mesh for planning/spec-generation (no jax device init).

    ``abstract_mesh((16, 16), ("data", "model"))`` works on every supported
    jax version regardless of the ``AbstractMesh`` constructor signature.
    """
    if len(shape) != len(axes):
        raise ValueError(f"shape {tuple(shape)} and axes {tuple(axes)} "
                         f"must have equal length")
    AbstractMesh = jax.sharding.AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))      # 0.4.37 shape_tuple
    except (TypeError, ValueError):
        return AbstractMesh(tuple(shape), tuple(axes))    # newer (shape, names)


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Any] = None,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None,
              auto: Optional[Any] = None):
    """``jax.shard_map`` with the new keyword surface on any jax version.

    ``axis_names`` lists the axes the body handles manually; on old jax it is
    translated to ``auto`` (its complement over the mesh axes). ``check_vma``
    maps to legacy ``check_rep``.
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        vma = check_vma if check_vma is not None else check_rep
        if vma is not None:
            kw["check_vma"] = vma
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
    from jax.experimental.shard_map import shard_map as legacy
    kw = {}
    rep = check_vma if check_vma is not None else check_rep
    if rep is not None:
        kw["check_rep"] = rep
    if auto is not None:
        kw["auto"] = frozenset(auto)
    elif axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
