"""Per-tenant SLO tracking: objective targets, rolling compliance, burn rate.

An :class:`SLO` names a latency objective ("propagation under 1s for 99% of
objects"); the :class:`SLOTracker` counts good/total observations per
(tenant, objective) in a rolling bucketed window and reports compliance and
**burn rate** — the ratio of the actual error rate to the error budget
implied by the target. Burn rate 1.0 means the tenant is consuming budget
exactly as fast as the objective allows; above 1.0 the objective will be
breached if the rate holds (the standard multiwindow-burn-rate alerting
quantity, here over a single rolling window).

Observations come from the tracing layer (the end-to-end propagation span
closing in the upward pipeline) and the serving plane (TTFT at request
finish). The tracker itself is tracer-independent and cheap enough to be
always on: one lock, a handful of ints per bucket.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class SLO:
    """A latency objective: ``target`` fraction of observations at or under
    ``threshold_s``, judged over a rolling ``window_s``."""
    name: str
    threshold_s: float
    target: float = 0.99
    window_s: float = 300.0


#: Objectives tracked out of the box. "propagation" is the paper's
#: tenant-write -> status-return path; "serving_ttft" is time to first token.
DEFAULT_OBJECTIVES: Tuple[SLO, ...] = (
    SLO("propagation", threshold_s=1.0, target=0.99, window_s=300.0),
    SLO("serving_ttft", threshold_s=0.5, target=0.95, window_s=300.0),
)

# rolling window is chopped into this many buckets; expiry granularity is
# window_s / buckets
_BUCKETS = 30


class SLOTracker:
    """Rolling good/total counts per (tenant, objective), surfaced on
    ``/healthz``. Unknown objective names are ignored (callers don't need
    to know which objectives a deployment configured)."""

    def __init__(self, objectives: Tuple[SLO, ...] = DEFAULT_OBJECTIVES,
                 buckets: int = _BUCKETS):
        self.objectives: Dict[str, SLO] = {o.name: o for o in objectives}
        self.buckets = max(2, int(buckets))
        self._lock = threading.Lock()
        # (tenant, objective) -> deque of [bucket_start, good, total]
        self._series: Dict[Tuple[str, str], Deque[List[float]]] = {}

    def observe(self, objective: str, tenant: str, value_s: float,
                now: Optional[float] = None) -> None:
        slo = self.objectives.get(objective)
        if slo is None:
            return
        if now is None:
            now = time.monotonic()
        width = slo.window_s / self.buckets
        bucket_start = now - (now % width)
        good = 1 if value_s <= slo.threshold_s else 0
        key = (tenant, objective)
        with self._lock:
            series = self._series.get(key)
            if series is None:
                series = self._series[key] = deque()
            if series and series[-1][0] == bucket_start:
                series[-1][1] += good
                series[-1][2] += 1
            else:
                series.append([bucket_start, good, 1])
            self._expire(series, slo, now)

    @staticmethod
    def _expire(series: Deque[List[float]], slo: SLO, now: float) -> None:
        horizon = now - slo.window_s
        while series and series[0][0] < horizon:
            series.popleft()

    def state(self, now: Optional[float] = None) -> Dict[str, Dict[str, Dict[str, float]]]:
        """``{tenant: {objective: {...compliance/burn_rate/...}}}`` over the
        rolling window. Tenants with no observations are absent."""
        if now is None:
            now = time.monotonic()
        out: Dict[str, Dict[str, Dict[str, float]]] = {}
        with self._lock:
            items = [(k, [list(b) for b in v]) for k, v in self._series.items()]
        for (tenant, objective), series_copy in items:
            slo = self.objectives[objective]
            horizon = now - slo.window_s
            good = total = 0
            for bucket_start, g, t in series_copy:
                if bucket_start >= horizon:
                    good += int(g)
                    total += int(t)
            if total == 0:
                continue
            compliance = good / total
            budget = 1.0 - slo.target
            if budget <= 0.0:
                burn = 0.0 if compliance >= 1.0 else float("inf")
            else:
                burn = (1.0 - compliance) / budget
            out.setdefault(tenant, {})[objective] = {
                "target": slo.target,
                "threshold_s": slo.threshold_s,
                "window_s": slo.window_s,
                "total": float(total),
                "good": float(good),
                "compliance": compliance,
                "burn_rate": burn,
                "breaching": compliance < slo.target,
            }
        return out
