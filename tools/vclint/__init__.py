"""vclint — repo-specific concurrency lint for the control plane.

Seven rules prove the invariants ARCHITECTURE.md documents under
"Concurrency invariants":

- VCL001 lock-order violations (cycles, store-lock-under-watch-lock)
- VCL002 blocking calls reachable from cooperative Task bodies
- VCL003 mutation of zero-copy (``copy=False``) store references
- VCL004 silent ``except Exception`` swallows
- VCL005 fields written both under a lock and bare
- VCL006 tracer ``start_span`` not used as a context manager
- VCL007 zero-copy refs retained past an audit/metering hook boundary

Run as ``PYTHONPATH=tools python -m vclint src`` from the repo root.
Deliberate violations live in ``tools/vclint/baseline.txt`` (one
fingerprint + justification per line); point suppressions use an
inline ``# vclint: disable=VCL00X <reason>`` pragma.
"""
from .engine import Finding, Rule, load_baseline, run
from .rules_blocking import BlockingCallRule
from .rules_excepts import SilentExceptRule
from .rules_locks import LockedElsewhereRule, LockOrderRule
from .rules_trace import SpanContextRule
from .rules_zerocopy import ZeroCopyMutationRule, ZeroCopyRetentionRule

ALL_RULES = [LockOrderRule, BlockingCallRule, ZeroCopyMutationRule,
             SilentExceptRule, LockedElsewhereRule, SpanContextRule,
             ZeroCopyRetentionRule]

__all__ = ["Finding", "Rule", "run", "load_baseline", "ALL_RULES",
           "LockOrderRule", "BlockingCallRule", "ZeroCopyMutationRule",
           "SilentExceptRule", "LockedElsewhereRule", "SpanContextRule",
           "ZeroCopyRetentionRule"]
