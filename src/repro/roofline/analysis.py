"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
i.e. all chips — divided by chip count). collective_bytes is parsed from the
post-SPMD optimized HLO text: per collective op we take the output buffer
size and the replica-group size n and charge ring-algorithm per-device send
bytes (all-reduce 2·S·(n-1)/n, all-gather S·(n-1)/n, reduce-scatter S·(n-1),
all-to-all S·(n-1)/n, collective-permute S).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?(?:,\s*)?)+)\s*(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    total_bytes: float = 0.0   # per-device send bytes

    def add(self, op: str, nbytes: float) -> None:
        self.counts[op] = self.counts.get(op, 0) + 1
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + nbytes
        self.total_bytes += nbytes


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        out_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-reduce":
            send = 2.0 * out_bytes * (n - 1) / n
        elif op == "all-gather":
            send = out_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            send = out_bytes * (n - 1)
        elif op == "all-to-all":
            send = out_bytes * (n - 1) / n
        else:  # collective-permute
            send = out_bytes
        stats.add(op, send)
    return stats


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m is not None:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m is not None:
        return int(m.group(2))
    if "source_target_pairs" in line:
        return 2
    return 0


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float                 # kernel-adjusted (deployment path)
    collective_bytes: float          # per device
    model_flops: float               # 6*N*D (active params)
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    bytes_per_device: float = 0.0    # from memory_analysis
    hlo_bytes_raw: float = 0.0       # XLA-fallback-path bytes (pre-adjust)
    bytes_by_region: Dict[str, float] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline fraction: useful model FLOP/s at the step-time lower
        bound, over peak."""
        t = self.step_time_lower_bound
        if t <= 0:
            return 0.0
        return self.model_flops / t / (self.chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "bytes_per_device": self.bytes_per_device,
            "collectives": self.collectives,
            "collective_counts": self.collective_counts,
            "hlo_bytes_raw": self.hlo_bytes_raw,
            "bytes_by_region": self.bytes_by_region,
        }


def kernel_region_traffic(cfg, shape) -> Dict[str, float]:
    """Analytic GLOBAL HBM bytes for the Pallas-kernel regions.

    The dry-run compiles the XLA fallback paths (Pallas cannot lower for the
    CPU host backend), whose interior intermediates (attention p-tensors,
    scan cumulants) hit HBM. On TPU those regions run as Pallas kernels with
    VMEM-resident interiors — their true HBM traffic is just the boundary
    tensors. We subtract the measured region bytes and add these analytic
    boundary numbers (train: fwd + remat-refwd + bwd ~= 4 boundary passes).
    """
    mode = shape.kind
    B, S = shape.global_batch, shape.seq_len
    bys = 2.0  # bf16 boundaries
    passes = 4.0 if mode == "train" else 1.0
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    n_attn = sum(1 for k in kinds if k in "gl")
    n_mamba = sum(1 for k in kinds if k == "m")
    n_rwkv = sum(1 for k in kinds if k == "r")
    H, KV, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    out: Dict[str, float] = {}
    if mode == "decode":
        # read the cache once + write the new entry; q/out negligible
        att = n_attn * (2 * B * S * KV * hd * bys + 4 * B * H * hd * bys)
    else:
        att = n_attn * passes * (2 * B * S * H * hd
                                 + 2 * B * S * KV * hd) * bys
    if cfg.is_encdec and mode != "decode":
        att += (cfg.n_enc_layers + cfg.n_layers) * passes * (
            2 * B * S * H * hd + 2 * B * S * KV * hd) * bys
    out["attention"] = att
    if mode == "decode":
        hs = cfg.rwkv_head_size
        out["rwkv"] = n_rwkv * (5 * B * D * bys + 2 * B * D * hs * 4.0)
        out["mamba"] = n_mamba * 2 * B * cfg.mamba_d_inner * (
            cfg.mamba_d_state + 3) * 4.0
    else:
        out["rwkv"] = n_rwkv * passes * 5 * B * S * D * bys
        out["mamba"] = n_mamba * passes * (
            3 * B * S * cfg.mamba_d_inner + 2 * B * S * cfg.mamba_d_state) * 4.0
    return out


def model_flops_for(cfg, shape, mode: str) -> float:
    """MODEL_FLOPS = 6*N*D (+3x attention term) for training, 2*N*D (+1x)
    for inference. The attention term (2*B*ceil(S^2/2)*H*hd*2 per layer,
    windowed layers capped at the window) is genuine useful work that the
    param-count convention misses — at 32k prefill it DOMINATES, so without
    it the roofline fraction would be nonsensically pessimistic."""
    n_active = cfg.num_active_params()
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.n_heads, cfg.head_dim

    def attn_fwd_flops() -> float:
        total = 0.0
        for i in range(cfg.n_layers):
            kind = cfg.layer_kind(i)
            if kind not in ("g", "l"):
                continue
            if mode == "decode":
                ctx = S if kind == "g" else min(S, cfg.sliding_window)
                total += 2.0 * 2.0 * B * ctx * H * hd
            else:
                ctx = (S / 2 if kind == "g"
                       else min(S, cfg.sliding_window))  # causal half / window
                total += 2.0 * 2.0 * B * S * ctx * H * hd / (
                    1.0 if kind == "l" else 1.0)
        if cfg.is_encdec and mode != "decode":
            total += cfg.n_enc_layers * 2.0 * 2.0 * B * S * S * H * hd
            total += cfg.n_layers * 2.0 * 2.0 * B * S * S * H * hd  # cross
        return total

    if mode == "train":
        return 6.0 * n_active * shape.tokens + 3.0 * attn_fwd_flops()
    if mode == "prefill":
        return 2.0 * n_active * shape.tokens + attn_fwd_flops()
    return 2.0 * n_active * shape.global_batch + attn_fwd_flops()
