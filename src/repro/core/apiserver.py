"""Per-control-plane API server.

Each tenant control plane and the super cluster own one APIServer wrapping a
dedicated ObjectStore (paper: "a dedicated etcd can be assigned to each tenant
control plane"). It adds:
- token-bucket request rate limiting (k8s built-in client rate limits);
- request metrics (the Fig.1 interference story becomes measurable);
- a bearer credential whose hash identifies the tenant (used by VnAgent);
- per-client handles (:meth:`APIServer.client`): thin views over the shared
  store, each with a dedicated token bucket, so independent callers (e.g.
  syncer shards) don't serialize on one bucket lock.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

from . import trace as trace_mod
from .executor import RetryLater, current_thread_pooled
from .objects import new_uid
from .store import ContinueToken, ObjectStore


class RateLimited(RetryLater):
    """Token bucket exhausted. Subclasses :class:`RetryLater`, so any
    controller already retrying RetryLater backs off instead of crashing
    when a burst empties its client's bucket on a pool thread."""


class TokenBucket:
    """qps/burst token bucket (client-go flowcontrol analogue)."""

    def __init__(self, qps: float = 10_000.0, burst: int = 20_000):
        self.qps = float(qps)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last = time.monotonic()
        self._lock = threading.Lock()

    def take(self, block: bool = True, n: int = 1) -> None:
        n = min(n, self.burst)   # a batch above burst capacity must not hang
        while True:
            with self._lock:
                now = time.monotonic()
                self._tokens = min(self.burst, self._tokens + (now - self._last) * self.qps)
                self._last = now
                if self._tokens >= n:
                    self._tokens -= n
                    return
                need = (n - self._tokens) / self.qps
            if not block or current_thread_pooled():
                # a cooperative pool thread must NEVER park here: stalling
                # one quantum stalls every task behind it. Raise instead —
                # RateLimited is a RetryLater, so reconcile loops requeue
                # the key with backoff and the pool keeps draining.
                raise RateLimited(
                    f"bucket empty for {need * 1e3:.1f}ms (qps={self.qps})")
            time.sleep(need)   # vclint: disable=VCL002 pool threads raise above


class APIClient:
    """Rate-limited CRUD/list/watch handle over a (possibly shared) ObjectStore.

    Every client has its OWN token bucket and request counters; many clients
    may front one store (the k8s picture: many connections, one apiserver
    storage). :class:`APIServer` is itself the default client that owns the
    store; extra handles come from :meth:`APIServer.client`.
    """

    def __init__(self, name: str, store: ObjectStore,
                 qps: float = 50_000.0, burst: int = 100_000):
        self.name = name
        self.store = store
        self.qps = qps
        self.burst = burst
        self._bucket = TokenBucket(qps, burst)
        self._lock = threading.Lock()
        self.request_count = 0
        self.request_latency_sum = 0.0
        # Optional per-tenant accountability hooks (attached by the
        # framework / AuditLog.attach). All three default to "off"; an
        # unwired client pays two attribute loads per request and is
        # otherwise the pre-audit code path.
        self.audit: Optional[Any] = None
        self.meter: Optional[Any] = None
        self.obs_tenant = ""

    def _req(self, fn: Callable[[], Any], tokens: int = 1, verb: str = "",
             kind: str = "", namespace: str = "", name: str = "",
             obj: Any = None) -> Any:
        if self.audit is None and self.meter is None:
            t0 = time.monotonic()
            self._bucket.take(n=tokens)
            out = fn()
            with self._lock:
                self.request_count += 1
                self.request_latency_sum += time.monotonic() - t0
            return out
        return self._req_observed(fn, tokens, verb, kind, namespace, name,
                                  obj)

    def _req_observed(self, fn: Callable[[], Any], tokens: int, verb: str,
                      kind: str, namespace: str, name: str, obj: Any) -> Any:
        t0 = time.monotonic()
        try:
            self._bucket.take(n=tokens)
            out = fn()
        except Exception as e:
            # failures are audited but (as before) do not bump the
            # request counters — the request never completed
            self._observe(verb, kind, namespace, name, obj,
                          type(e).__name__, time.monotonic() - t0, tokens)
            raise
        dt = time.monotonic() - t0
        with self._lock:
            self.request_count += 1
            self.request_latency_sum += dt
        self._observe(verb, kind, namespace, name, obj, "ok", dt, tokens)
        return out

    def _observe(self, verb: str, kind: str, namespace: str, name: str,
                 obj: Any, outcome: str, latency_s: float,
                 count: int) -> None:
        """Extract ONLY scalars from the subject — ``obj`` may be a
        ``copy=False`` store internal; retaining it (or any of its mutable
        containers) past this hook would alias live store state."""
        tenant = self.obs_tenant or self.name
        if obj is not None:
            if not kind:
                kind = getattr(type(obj), "kind", "")
            md = obj.metadata
            namespace = md.namespace
            name = md.name
        meter = self.meter
        if meter is not None:
            meter.add(tenant, "api_requests", float(count))
        audit = self.audit
        if audit is not None:
            tp: Optional[str] = None
            if obj is not None:
                tp = obj.metadata.annotations.get(trace_mod.TRACEPARENT_KEY)
                if tp is not None and not trace_mod.sampled_carrier(tp):
                    tp = None
            audit.record(tenant, verb, kind, namespace, name, outcome,
                         latency_s, count=count, traceparent=tp)

    # -- API surface ---------------------------------------------------------

    def create(self, obj: Any) -> Any:
        return self._req(lambda: self.store.create(obj),
                         verb="create", obj=obj)

    def create_batch(self, objs: List[Any]) -> Tuple[List[Any], List[Any]]:
        """Batched create: one request, ``len(objs)`` rate-limit tokens.
        Returns ``(created, conflicted)`` (see ``ObjectStore.create_many``)."""
        return self._req(lambda: self.store.create_many(objs),
                         tokens=max(1, len(objs)), verb="create_batch",
                         obj=objs[0] if objs else None)

    def get(self, kind: str, namespace: str, name: str) -> Any:
        return self._req(lambda: self.store.get(kind, namespace, name),
                         verb="get", kind=kind, namespace=namespace,
                         name=name)

    def update(self, obj: Any, *, force: bool = False) -> Any:
        return self._req(lambda: self.store.update(obj, force=force),
                         verb="update", obj=obj)

    def update_batch(self, objs: List[Any], *, force: bool = False
                     ) -> Tuple[List[Any], List[Any]]:
        """Batched update: one request, ``len(objs)`` rate-limit tokens.
        Returns ``(updated, conflicted)`` (see ``ObjectStore.update_many``)."""
        return self._req(lambda: self.store.update_many(objs, force=force),
                         tokens=max(1, len(objs)), verb="update_batch",
                         obj=objs[0] if objs else None)

    def update_status(self, kind: str, namespace: str, name: str,
                      mutate: Callable[[Any], None]) -> Any:
        return self._req(lambda: self.store.update_status(kind, namespace, name, mutate),
                         verb="update_status", kind=kind,
                         namespace=namespace, name=name)

    def update_status_batch(self, updates: List[Tuple[str, str, str,
                                                      Callable[[Any], None]]]
                            ) -> Tuple[List[Any], List[Tuple[str, str, str]]]:
        """Batched status RMW: one request, ``len(updates)`` rate-limit
        tokens. Returns ``(updated, missing)`` (see
        ``ObjectStore.update_status_many``)."""
        return self._req(lambda: self.store.update_status_many(updates),
                         tokens=max(1, len(updates)),
                         verb="update_status_batch",
                         kind=updates[0][0] if updates else "",
                         namespace=updates[0][1] if updates else "")

    def delete(self, kind: str, namespace: str, name: str) -> Any:
        return self._req(lambda: self.store.delete(kind, namespace, name),
                         verb="delete", kind=kind, namespace=namespace,
                         name=name)

    def delete_batch(self, keys: List[Tuple[str, str, str]]
                     ) -> Tuple[List[Any], List[Tuple[str, str, str]]]:
        """Batched delete: one request, ``len(keys)`` rate-limit tokens.
        Returns ``(deleted, missing)`` (see ``ObjectStore.delete_many``)."""
        return self._req(lambda: self.store.delete_many(keys),
                         tokens=max(1, len(keys)), verb="delete_batch",
                         kind=keys[0][0] if keys else "",
                         namespace=keys[0][1] if keys else "")

    def list(self, kind: str, namespace: Optional[str] = None, *,
             copy: bool = True) -> List[Any]:
        """Snapshot LIST. ``copy=False`` returns the stored refs (READ-ONLY
        contract) for trusted in-process consumers — zero deepcopy cost."""
        return self._req(lambda: self.store.list(kind, namespace, copy=copy),
                         verb="list", kind=kind, namespace=namespace or "")

    def list_paged(self, kind: str, namespace: Optional[str] = None, *,
                   limit: int = 500,
                   continue_token: Optional[ContinueToken] = None,
                   copy: bool = True
                   ) -> Tuple[List[Any], Optional[ContinueToken], int]:
        """One page of a k8s-style paged LIST: ``(page, continue_token, rv)``.
        Pass the returned token back to fetch the next page (None = done);
        all pages are consistent at ``rv``. Each page costs one rate-limit
        token — a cold 100k-object LIST no longer starves the bucket."""
        return self._req(lambda: self.store.list_page(
            kind, namespace, limit=limit, continue_token=continue_token,
            copy=copy), verb="list", kind=kind, namespace=namespace or "")

    def list_all_pages(self, kind: str, namespace: Optional[str] = None, *,
                       limit: int = 500, copy: bool = True
                       ) -> Tuple[List[Any], int]:
        """Drain every page of a paged LIST: ``(objects, rv)``. The rv is
        the snapshot version — resume a watch from it to catch up."""
        out: List[Any] = []
        token: Optional[ContinueToken] = None
        while True:
            page, token, rv = self.list_paged(
                kind, namespace, limit=limit, continue_token=token, copy=copy)
            out.extend(page)
            if token is None:
                return out, rv

    def watch(self, kind: str, namespace: Optional[str] = None, *,
              from_rv: Optional[int] = None, copy: bool = True,
              buffer: int = 100_000):
        """Open a watch; ``from_rv`` resumes from a resourceVersion (raises
        ``ResourceVersionExpired`` when the backlog no longer covers it),
        ``copy=False`` streams shared READ-ONLY refs (zero-copy events),
        ``buffer`` bounds the channel (overflow closes the stream)."""
        return self.store.watch(kind, namespace, from_rv=from_rv, copy=copy,
                                buffer=buffer)

    def list_and_watch(self, kind: str, namespace: Optional[str] = None, *,
                       copy: bool = True):
        return self._req(lambda: self.store.list_and_watch(kind, namespace,
                                                           copy=copy),
                         verb="list_and_watch", kind=kind,
                         namespace=namespace or "")


class APIServer(APIClient):
    """The store-owning client plus server-side identity and lifecycle."""

    def __init__(self, name: str, qps: float = 50_000.0, burst: int = 100_000):
        super().__init__(name, ObjectStore(name), qps, burst)
        self.credential = new_uid()          # bearer token for this plane

    @property
    def credential_hash(self) -> str:
        return hashlib.sha256(self.credential.encode()).hexdigest()[:16]

    def client(self, name: str, qps: Optional[float] = None,
               burst: Optional[int] = None) -> APIClient:
        """A dedicated client handle: same store, its own token bucket.
        Inherits the server's audit/meter attribution, so per-shard handles
        over a tenant plane keep accounting to that tenant."""
        c = APIClient(f"{self.name}/{name}", self.store,
                      qps if qps is not None else self.qps,
                      burst if burst is not None else self.burst)
        c.audit = self.audit
        c.meter = self.meter
        c.obs_tenant = self.obs_tenant
        return c

    def close(self) -> None:
        self.store.close()


class TenantControlPlane:
    """A dedicated tenant control plane (apiserver + store, no scheduler).

    Paper §III-B: "a tenant control plane does not need a scheduler since the
    Pod scheduling is done in the super cluster."
    """

    def __init__(self, name: str, weight: int = 1):
        self.name = name
        self.weight = weight
        self.api = APIServer(f"tenant:{name}")
        # fixed attribution labels: a tenant plane is single-tenant by
        # construction, so audit/meter hooks attached later need no lookup
        self.api.obs_tenant = name
        self.api.store.meter_tenant = name

    def kubeconfig(self) -> dict:
        """Access credential stored in the super cluster by the operator."""
        return {"tenant": self.name, "credential": self.api.credential}

    def close(self) -> None:
        self.api.close()
