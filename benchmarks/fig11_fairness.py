"""Fig.11: impact of fair queuing on fairness.

Paper §IV-D: 10 greedy tenants issue 900 creations concurrently each; 40
regular tenants issue 10 sequentially each; all weights equal. With WRR fair
queuing the regular tenants' average creation time stays small; with the
shared FIFO they are starved behind the greedy burst.

Beyond the paper, the sweep re-runs the fair configuration with the syncer
sharded 4-ways (tenants hash-partitioned, per-shard WRR) to show the
fairness guarantee survives horizontal scaling.
"""
from __future__ import annotations

import statistics
import threading
import time
from typing import Dict, List

from repro.core import Namespace
from .common import make_framework, syncer_metrics_summary


def _run_one(fair: bool, greedy: int, greedy_units: int, regular: int,
             regular_units: int, shards: int = 1) -> Dict:
    fw = make_framework(100, fair_queuing=fair, syncer_shards=shards)
    fw.start()
    try:
        gplanes = [fw.add_tenant(f"greedy{i:02d}") for i in range(greedy)]
        rplanes = [fw.add_tenant(f"reg{i:02d}") for i in range(regular)]
        for p in gplanes + rplanes:
            ns = Namespace()
            ns.metadata.name = "bench"
            p.api.create(ns)

        def greedy_submit(plane):
            for j in range(greedy_units):     # burst: all at once
                plane.api.create(fw.make_unit(f"g{j:05d}", "bench", chips=0))

        def regular_submit(plane):
            for j in range(regular_units):    # sequential: wait each Ready
                plane.api.create(fw.make_unit(f"r{j:05d}", "bench", chips=0))
                fw.wait_ready(plane, "bench", f"r{j:05d}", timeout=300)

        threads = [threading.Thread(target=greedy_submit, args=(p,))
                   for p in gplanes]
        threads += [threading.Thread(target=regular_submit, args=(p,))
                    for p in rplanes]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for p in gplanes:
            fw.wait_all_ready(p, "bench", greedy_units, timeout=600)

        def avg_latency(planes) -> List[float]:
            outs = []
            for p in planes:
                lats = []
                for u in p.api.list("WorkUnit", "bench"):
                    c = u.status.condition("Ready")
                    if c and c.status == "True":
                        lats.append(c.last_transition_time
                                    - u.metadata.creation_timestamp)
                if lats:
                    outs.append(statistics.mean(lats))
            return outs

        return {"greedy_avg_s": avg_latency(gplanes),
                "regular_avg_s": avg_latency(rplanes),
                "runtime_metrics": syncer_metrics_summary(fw)}
    finally:
        fw.stop()


def run(full: bool = False) -> List[Dict]:
    greedy, gu, regular, ru = (10, 900, 40, 10) if full else (4, 150, 12, 5)
    out = []
    # (fair_queuing, syncer_shards): paper's fair-vs-FIFO pair, plus the
    # fair configuration at 4 shards (fairness preserved under sharding)
    for fair, shards in ((True, 1), (False, 1), (True, 4)):
        r = _run_one(fair, greedy, gu, regular, ru, shards=shards)
        reg_worst = max(r["regular_avg_s"]) if r["regular_avg_s"] else 0.0
        reg_mean = statistics.mean(r["regular_avg_s"]) if r["regular_avg_s"] else 0.0
        gr_mean = statistics.mean(r["greedy_avg_s"]) if r["greedy_avg_s"] else 0.0
        suffix = "" if shards == 1 else f"_shards{shards}"
        rec = {
            "name": f"fig11/{'fair' if fair else 'fifo'}{suffix}",
            "fair_queuing": fair, "syncer_shards": shards,
            "greedy_tenants": greedy, "greedy_units_each": gu,
            "regular_tenants": regular, "regular_units_each": ru,
            "regular_mean_s": reg_mean, "regular_worst_s": reg_worst,
            "greedy_mean_s": gr_mean,
            "runtime_metrics": r["runtime_metrics"],
        }
        out.append(rec)
        print(f"  fig11 fair={fair} shards={shards}: regular mean "
              f"{reg_mean:.2f}s worst {reg_worst:.2f}s | greedy mean "
              f"{gr_mean:.2f}s", flush=True)
    return out
