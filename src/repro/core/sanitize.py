"""REPRO_SANITIZE=1 runtime sanitizer: the dynamic half of vclint.

Static rules (tools/vclint) prove what they can see; this module catches
what they can't. When ``REPRO_SANITIZE=1``:

- the store hands out **deep-frozen proxies** for all ``copy=False``
  reads (LIST pages, snapshots, zero-copy watch events). A proxy is a
  dynamically created *subclass* of the real object class — ``isinstance``
  checks, ``type(obj).kind`` lookups, ``dataclasses.fields`` and
  field-wise ``==`` all keep working — but any attribute/item mutation
  raises :class:`ZeroCopyMutationError` immediately, with the site that
  acquired the reference in the message;
- a **lock-hold watchdog** wraps the store lock and times executor quanta:
  holds/quanta longer than ``REPRO_SANITIZE_LOCK_MS`` /
  ``REPRO_SANITIZE_QUANTUM_MS`` are counted and reported to stderr
  (bounded; never raises — latency warts are reported, not fatal).

The flag is read once per ObjectStore/CooperativeExecutor construction,
so tests can monkeypatch the env var and build fresh instances. With the
env var unset every hook is a no-op and behavior is byte-identical.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import threading
import time
from typing import Any, Dict, Optional

_TRUTHY = ("1", "true", "yes", "on")


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() in _TRUTHY


def long_quantum_seconds() -> float:
    return float(os.environ.get("REPRO_SANITIZE_QUANTUM_MS", "500")) / 1e3


def lock_warn_seconds() -> float:
    return float(os.environ.get("REPRO_SANITIZE_LOCK_MS", "200")) / 1e3


class ZeroCopyMutationError(RuntimeError):
    """A consumer mutated a ``copy=False`` (shared, READ-ONLY) store ref."""


# ----------------------------------------------------------------- reporting

long_hold_reports = 0
_MAX_STDERR_REPORTS = 25
_report_lock = threading.Lock()


def report_long_hold(msg: str) -> None:
    """Count a watchdog trip; echo the first few to stderr."""
    global long_hold_reports
    with _report_lock:
        long_hold_reports += 1
        n = long_hold_reports
    if n <= _MAX_STDERR_REPORTS:
        print(f"[sanitize] {msg}", file=sys.stderr)


# ------------------------------------------------------------- frozen proxies

def _acquire_site() -> str:
    """First stack frame outside the store/sanitizer plumbing — the consumer
    that asked for the zero-copy ref."""
    f = sys._getframe(1)
    skip = ("sanitize.py", "store.py", "apiserver.py")
    while f is not None:
        fname = f.f_code.co_filename
        if not fname.endswith(skip):
            return (f"{os.path.basename(fname)}:{f.f_lineno} "
                    f"in {f.f_code.co_name}")
        f = f.f_back
    return "<unknown>"


def _mutation_error(obj: Any, what: str) -> ZeroCopyMutationError:
    site = getattr(obj, "__acquired_at__", "<unknown>")
    base = getattr(type(obj), "__frozen_base__", type(obj))
    return ZeroCopyMutationError(
        f"{what} on a zero-copy (copy=False) {base.__name__} ref — these "
        f"are shared READ-ONLY store state; deepcopy_obj() before "
        f"mutating. Ref acquired at {site}.")


class FrozenDict(dict):
    __slots__ = ("__acquired_at__",)

    def _refuse(self, what: str) -> None:
        raise _mutation_error(self, what)

    def __setitem__(self, k: Any, v: Any) -> None:
        self._refuse(f"item assignment [{k!r}]")

    def __delitem__(self, k: Any) -> None:
        self._refuse(f"item deletion [{k!r}]")

    def clear(self) -> None:                          # type: ignore[override]
        self._refuse(".clear()")

    def pop(self, *a: Any) -> Any:                    # type: ignore[override]
        self._refuse(".pop()")

    def popitem(self) -> Any:                         # type: ignore[override]
        self._refuse(".popitem()")

    def setdefault(self, *a: Any) -> Any:             # type: ignore[override]
        self._refuse(".setdefault()")

    def update(self, *a: Any, **kw: Any) -> None:     # type: ignore[override]
        self._refuse(".update()")

    def __ior__(self, other: Any) -> Any:
        self._refuse("|= update")


class FrozenList(list):
    __slots__ = ("__acquired_at__",)

    def _refuse(self, what: str) -> None:
        raise _mutation_error(self, what)

    def __setitem__(self, i: Any, v: Any) -> None:
        self._refuse(f"item assignment [{i!r}]")

    def __delitem__(self, i: Any) -> None:
        self._refuse(f"item deletion [{i!r}]")

    def append(self, v: Any) -> None:                 # type: ignore[override]
        self._refuse(".append()")

    def extend(self, it: Any) -> None:                # type: ignore[override]
        self._refuse(".extend()")

    def insert(self, i: int, v: Any) -> None:         # type: ignore[override]
        self._refuse(".insert()")

    def remove(self, v: Any) -> None:                 # type: ignore[override]
        self._refuse(".remove()")

    def pop(self, *a: Any) -> Any:                    # type: ignore[override]
        self._refuse(".pop()")

    def clear(self) -> None:                          # type: ignore[override]
        self._refuse(".clear()")

    def sort(self, *a: Any, **kw: Any) -> None:       # type: ignore[override]
        self._refuse(".sort()")

    def reverse(self) -> None:                        # type: ignore[override]
        self._refuse(".reverse()")

    def __iadd__(self, other: Any) -> Any:
        self._refuse("+= extend")


_frozen_classes: Dict[type, type] = {}
_frozen_lock = threading.Lock()


def _frozen_class(base: type) -> type:
    with _frozen_lock:
        cls = _frozen_classes.get(base)
        if cls is not None:
            return cls

        def _setattr(self: Any, name: str, value: Any) -> None:
            raise _mutation_error(self, f"attribute assignment .{name}")

        def _delattr(self: Any, name: str) -> None:
            raise _mutation_error(self, f"attribute deletion .{name}")

        def _eq(self: Any, other: Any) -> Any:
            b = type(self).__frozen_base__
            if dataclasses.is_dataclass(b) and isinstance(other, b):
                return all(
                    getattr(self, f.name) == getattr(other, f.name)
                    for f in dataclasses.fields(b))
            return NotImplemented

        def _ne(self: Any, other: Any) -> Any:
            eq = _eq(self, other)
            return eq if eq is NotImplemented else not eq

        ns = {
            "__frozen_base__": base,
            "__setattr__": _setattr,
            "__delattr__": _delattr,
        }
        if dataclasses.is_dataclass(base):
            # dataclass __eq__ is class-identity-gated; replace with a
            # field-wise one so frozen-vs-plain spec comparisons still work
            ns["__eq__"] = _eq
            ns["__ne__"] = _ne
            ns["__hash__"] = base.__hash__
        cls = type("Frozen" + base.__name__, (base,), ns)
        _frozen_classes[base] = cls
        return cls


def freeze(obj: Any, site: Optional[str] = None) -> Any:
    """Deep-frozen proxy of ``obj``; scalars pass through unchanged."""
    if site is None:
        site = _acquire_site()
    if obj is None or isinstance(obj, (str, int, float, bool, bytes,
                                       frozenset)):
        return obj
    if getattr(type(obj), "__frozen_base__", None) is not None \
            or isinstance(obj, (FrozenDict, FrozenList)):
        return obj
    if isinstance(obj, dict):
        d = FrozenDict({k: freeze(v, site) for k, v in obj.items()})
        object.__setattr__(d, "__acquired_at__", site)
        return d
    if isinstance(obj, (list, tuple)):
        items = [freeze(v, site) for v in obj]
        if isinstance(obj, tuple):
            return tuple(items)
        fl = FrozenList(items)
        object.__setattr__(fl, "__acquired_at__", site)
        return fl
    if hasattr(obj, "__dict__"):
        cls = _frozen_class(type(obj))
        proxy = object.__new__(cls)
        for k, v in vars(obj).items():
            object.__setattr__(proxy, k, freeze(v, site))
        object.__setattr__(proxy, "__acquired_at__", site)
        return proxy
    return obj


def freeze_all(objs: Any) -> list:
    """Freeze a sequence with one shared acquisition site (list/page path)."""
    site = _acquire_site()
    return [freeze(o, site) for o in objs]


def maybe_freeze(obj: Any, active: bool) -> Any:
    """Store hook: freeze only when that store was built with the
    sanitizer active (one branch in the fast path otherwise)."""
    if not active:
        return obj
    return freeze(obj, _acquire_site())


# -------------------------------------------------------------- lock watchdog

class WatchdogLock:
    """Wraps an (R)Lock; wall-times each thread's outermost hold and
    reports holds longer than ``warn_seconds``. Never raises, never
    changes locking semantics."""

    def __init__(self, inner: Any, name: str,
                 warn_seconds: Optional[float] = None):
        self._inner = inner
        self._name = name
        self._warn_s = (lock_warn_seconds() if warn_seconds is None
                        else warn_seconds)
        self._tl = threading.local()
        self.long_holds = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            depth = getattr(self._tl, "depth", 0)
            if depth == 0:
                self._tl.t0 = time.monotonic()
            self._tl.depth = depth + 1
        return ok

    def release(self) -> None:
        depth = getattr(self._tl, "depth", 1) - 1
        self._tl.depth = depth
        if depth == 0:
            held = time.monotonic() - self._tl.t0
            if held > self._warn_s:
                self.long_holds += 1
                report_long_hold(
                    f"lock {self._name!r} held {held * 1e3:.0f}ms "
                    f"(> {self._warn_s * 1e3:.0f}ms) by "
                    f"{threading.current_thread().name}")
        self._inner.release()

    def __enter__(self) -> "WatchdogLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()
