"""Pure-jnp oracle for the RWKV6 (Finch) wkv scan: exact per-step recurrence.

State S [B, H, dk, dv]; per step t:
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with data-dependent per-channel decay w_t in (0, 1).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   w: jnp.ndarray, u: jnp.ndarray,
                   state: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w: [B, S, H, D]; u: [H, D]; state: [B, H, D, D] (k-major).

    Returns (out [B, S, H, D], final state [B, H, D, D]).
    """
    B, S, H, D = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                    # [B, H, D]
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,Dk,Dv]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[..., :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    state, outs = jax.lax.scan(step, state, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), state
