"""Unified controller runtime: one reconciler engine for the whole control
plane (paper §III-C, Fig.3/5).

Every VirtualCluster controller shares one architecture — informers feed a
keyed work queue, rate-limited workers call ``reconcile(key)``, and an
optional periodic scan remediates rare inconsistencies. This module extracts
that machinery once so the syncer, scheduler, router, tenant operator, and
node agents declare only *what* they reconcile, not threads or lifecycle:

- ``Controller``   — declared informers + a work queue (plain, delaying, or
  per-tenant fair) + a ``reconcile(key)`` callback with per-key
  exponential-backoff retries + an optional periodic ``scan()``;
- ``ControllerManager`` — start/stop lifecycle in dependency order, health
  checks, and a process-wide ``MetricsRegistry``;
- ``MetricsRegistry``   — counters, latency summaries, and live gauges
  (queue depth, reconcile latency, retries, scan cost) shared by every
  controller in the process.

Two scheduling modes, switched by the ``executor`` attribute (set directly
or adopted from the :class:`ControllerManager`):

- **cooperative** (executor set): informer pumps, reconcile workers, and the
  periodic scan are tasks on a shared
  :class:`~repro.core.executor.CooperativeExecutor` — thread count is
  O(pool size) regardless of controller/worker/informer count, and delayed
  retries ride the executor's single timer wheel;
- **blocking fallback** (executor ``None``): the legacy one-thread-per-
  worker/informer/scan mode, kept so the two paths stay bisectable.
"""
from __future__ import annotations

import threading
import time
from bisect import bisect_right
from typing import (Any, Callable, Dict, Hashable, List, Optional, Tuple,
                    Type)

from .apiserver import APIServer
from .executor import CooperativeExecutor, RetryLater, Task
from .fairqueue import FairWorkQueue
from .informer import Informer
from .workqueue import DelayingQueue, RateLimiter, WorkQueue

# RetryLater is re-exported here for the existing import surface (agent.py,
# syncer.py, tests); the class itself moved to executor.py so leaf modules
# (apiserver.py) can raise it without importing the controller runtime.
__all__ = ["RetryLater", "MetricsRegistry", "Histogram", "Controller",
           "ControllerManager", "prometheus_text",
           "PROMETHEUS_CONTENT_TYPE"]


# --------------------------------------------------------------------- metrics

class Histogram:
    """Log-spaced latency histogram: mergeable, with exact-ish percentiles.

    ``bounds[i] = start * factor**i`` — the defaults span 100µs to ~14min in
    24 buckets, fine enough that p50/p90/p99 land within one factor-of-2
    bucket of truth (log-linear interpolation inside the bucket tightens
    that further). Unlike the ``[sum, count, max]`` summaries, a histogram
    answers percentile queries over its whole lifetime in O(buckets) and
    two histograms with the same bounds merge by adding counts (per-tenant
    series roll up into fleet-wide ones).

    Self-locking: ``observe`` takes only the histogram's own lock, never the
    registry lock, so the hot path can't contend with ``snapshot()``.
    """

    __slots__ = ("bounds", "counts", "sum", "count", "max", "_lock")

    def __init__(self, *, start: float = 1e-4, factor: float = 2.0,
                 buckets: int = 24,
                 bounds: Optional[Tuple[float, ...]] = None):
        if bounds is not None:
            self.bounds = tuple(bounds)
        else:
            self.bounds = tuple(start * factor ** i for i in range(buckets))
        self.counts = [0] * (len(self.bounds) + 1)   # last = overflow
        self.sum = 0.0
        self.count = 0
        self.max = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float, n: int = 1) -> None:
        if n <= 0:
            return
        idx = bisect_right(self.bounds, value)
        with self._lock:
            self.counts[idx] += n
            self.sum += value * n
            self.count += n
            if value > self.max:
                self.max = value

    def merge(self, other: "Histogram") -> None:
        """Add ``other``'s counts into this histogram (same bounds only)."""
        if other.bounds != self.bounds:
            raise ValueError("cannot merge histograms with different bounds")
        with other._lock:
            counts = list(other.counts)
            osum, ocount, omax = other.sum, other.count, other.max
        with self._lock:
            for i, c in enumerate(counts):
                self.counts[i] += c
            self.sum += osum
            self.count += ocount
            if omax > self.max:
                self.max = omax

    def percentile(self, p: float) -> float:
        """Estimate the ``p``-th percentile (0-100) by cumulative walk with
        log-linear interpolation inside the landing bucket."""
        with self._lock:
            total = self.count
            counts = list(self.counts)
            hi = self.max
        if total == 0:
            return 0.0
        rank = max(1.0, (min(100.0, max(0.0, p)) / 100.0) * total)
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                frac = (rank - cum) / c
                if i == 0:
                    lo_b, hi_b = 0.0, self.bounds[0]
                    return lo_b + frac * (hi_b - lo_b)
                if i == len(self.bounds):
                    # overflow bucket: bounded above by the observed max
                    lo_b = self.bounds[-1]
                    return lo_b + frac * (max(hi, lo_b) - lo_b)
                lo_b, hi_b = self.bounds[i - 1], self.bounds[i]
                # log-linear: latency mass is multiplicative within a bucket
                return lo_b * (hi_b / lo_b) ** frac
            cum += c
        return hi

    def state(self) -> Dict[str, float]:
        with self._lock:
            count, total, mx = self.count, self.sum, self.max
        return {"count": float(count), "sum": total,
                "mean": total / count if count else 0.0, "max": mx,
                "p50": self.percentile(50.0), "p90": self.percentile(90.0),
                "p99": self.percentile(99.0)}

class MetricsRegistry:
    """Process-wide controller metrics: counters, summaries, gauges.

    Keys are ``name`` plus sorted ``{label=value}`` pairs, Prometheus-style
    (``reconcile_total{controller=scheduler}``). Gauges are callables
    evaluated at snapshot time (e.g. live queue depth).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._summaries: Dict[str, List[float]] = {}   # [sum, count, max]
        self._gauges: Dict[str, Callable[[], float]] = {}
        self._hists: Dict[str, Histogram] = {}
        self.gauge_errors = 0   # snapshot() gauge callables that raised

    @staticmethod
    def _key(name: str, labels: Dict[str, Any]) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
        return f"{name}{{{inner}}}"

    def inc(self, name: str, value: float = 1.0, **labels: Any) -> None:
        key = self._key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + value

    def observe(self, name: str, value: float, **labels: Any) -> None:
        key = self._key(name, labels)
        with self._lock:
            s = self._summaries.setdefault(key, [0.0, 0.0, 0.0])
            s[0] += value
            s[1] += 1
            s[2] = max(s[2], value)

    def observe_n(self, name: str, value: float, n: int = 1,
                  **labels: Any) -> None:
        """``n`` observations of ``value`` in ONE lock round (batch-path
        accounting: per-item summary semantics without per-item locking)."""
        if n <= 0:
            return
        key = self._key(name, labels)
        with self._lock:
            s = self._summaries.setdefault(key, [0.0, 0.0, 0.0])
            s[0] += value * n
            s[1] += n
            s[2] = max(s[2], value)

    def register_gauge(self, name: str, fn: Callable[[], float],
                       **labels: Any) -> None:
        key = self._key(name, labels)
        with self._lock:
            self._gauges[key] = fn

    def histogram(self, name: str, **labels: Any) -> Histogram:
        """Get-or-create the named histogram. The registry lock covers only
        the lookup; the returned histogram self-locks its observes, so hot
        paths should hold onto the reference."""
        key = self._key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = Histogram()
        return h

    def counter(self, name: str, **labels: Any) -> float:
        with self._lock:
            return self._counters.get(self._key(name, labels), 0.0)

    def summary(self, name: str, **labels: Any) -> Dict[str, float]:
        with self._lock:
            s = self._summaries.get(self._key(name, labels))
        if s is None:
            return {"sum": 0.0, "count": 0.0, "mean": 0.0, "max": 0.0}
        return {"sum": s[0], "count": s[1],
                "mean": s[0] / s[1] if s[1] else 0.0, "max": s[2]}

    def snapshot(self) -> Dict[str, Any]:
        # hold the registry lock only long enough to copy raw state; summary
        # shaping, gauge callables (which may be arbitrarily slow), and
        # histogram percentile walks all run outside it, so a stalled gauge
        # cannot block every inc()/observe() on the hot path
        with self._lock:
            counters = dict(self._counters)
            raw_summaries = {k: tuple(s) for k, s in self._summaries.items()}
            gauges = list(self._gauges.items())
            hists = list(self._hists.items())
        summaries = {k: {"sum": s[0], "count": s[1],
                         "mean": s[0] / s[1] if s[1] else 0.0,
                         "max": s[2]}
                     for k, s in raw_summaries.items()}
        out_gauges: Dict[str, float] = {}
        errors = 0
        for key, fn in gauges:
            try:
                out_gauges[key] = float(fn())
            except Exception:
                # a broken gauge must not break /metrics, but it must be
                # visible: NaN in the scrape plus an error counter
                errors += 1
                out_gauges[key] = float("nan")
        if errors:
            with self._lock:
                self.gauge_errors += errors
        return {"counters": counters, "summaries": summaries,
                "gauges": out_gauges,
                "histograms": {k: h.state() for k, h in hists}}


# ------------------------------------------------- Prometheus text exposition

#: Content type of the rendered exposition (text format 0.0.4).
PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if (ch.isalnum() or ch in "_:") else "_")
    s = "".join(out)
    return s if s and not s[0].isdigit() else "_" + s


def _prom_parse_key(key: str) -> Tuple[str, List[Tuple[str, str]]]:
    """Split a registry key ``name{a=b,c=d}`` into (name, label pairs).
    Label values in this codebase never contain ``,``/``=`` (tenant,
    controller, and informer names), so the flat split is exact."""
    name, brace, rest = key.partition("{")
    labels: List[Tuple[str, str]] = []
    if brace:
        for pair in rest.rstrip("}").split(","):
            k, _, v = pair.partition("=")
            labels.append((k, v))
    return _prom_name(name), labels


def _prom_sample(name: str, labels: List[Tuple[str, str]],
                 value: Any) -> str:
    v = float(value)
    val = "NaN" if v != v else repr(v)
    if not labels:
        return f"{name} {val}"
    inner = ",".join(
        '{}="{}"'.format(_prom_name(k),
                         str(v2).replace("\\", "\\\\").replace('"', '\\"')
                         .replace("\n", "\\n"))
        for k, v2 in labels)
    return f"{name}{{{inner}}} {val}"


def prometheus_text(snapshot: Dict[str, Any]) -> str:
    """Render a :meth:`MetricsRegistry.snapshot` in Prometheus text
    exposition format (0.0.4), for standard scrapers.

    Counters and gauges map 1:1. Summaries render as ``TYPE summary``
    (``<name>_sum``/``<name>_count``), histograms as quantile summaries
    under the ``<name>_hist`` family — a histogram may share its base name
    with a summary (e.g. ``serving_ttft_seconds``), so the suffix keeps
    the two families distinct. ``max`` fields land in trailing
    ``*_max`` gauge families.
    """
    lines: List[str] = []
    max_families: Dict[str, List[Tuple[List[Tuple[str, str]], float]]] = {}

    def grouped(section: Dict[str, Any]
                ) -> List[Tuple[str, List[Tuple[List[Tuple[str, str]], Any]]]]:
        groups: Dict[str, List[Tuple[List[Tuple[str, str]], Any]]] = {}
        for key, val in section.items():
            name, labels = _prom_parse_key(key)
            groups.setdefault(name, []).append((labels, val))
        return [(n, sorted(groups[n], key=lambda e: e[0]))
                for n in sorted(groups)]

    for mtype, section_name in (("counter", "counters"),
                                ("gauge", "gauges")):
        for name, entries in grouped(snapshot.get(section_name, {})):
            lines.append(f"# TYPE {name} {mtype}")
            for labels, val in entries:
                lines.append(_prom_sample(name, labels, val))
    for name, entries in grouped(snapshot.get("summaries", {})):
        lines.append(f"# TYPE {name} summary")
        for labels, s in entries:
            lines.append(_prom_sample(name + "_sum", labels, s.get("sum", 0.0)))
            lines.append(_prom_sample(name + "_count", labels,
                                      s.get("count", 0.0)))
            max_families.setdefault(name + "_max", []).append(
                (labels, float(s.get("max", 0.0))))
    for name, entries in grouped(snapshot.get("histograms", {})):
        fam = name + "_hist"
        lines.append(f"# TYPE {fam} summary")
        for labels, h in entries:
            for q, field in (("0.5", "p50"), ("0.9", "p90"), ("0.99", "p99")):
                lines.append(_prom_sample(
                    fam, labels + [("quantile", q)], h.get(field, 0.0)))
            lines.append(_prom_sample(fam + "_sum", labels, h.get("sum", 0.0)))
            lines.append(_prom_sample(fam + "_count", labels,
                                      h.get("count", 0.0)))
            max_families.setdefault(fam + "_max", []).append(
                (labels, float(h.get("max", 0.0))))
    for name in sorted(max_families):
        lines.append(f"# TYPE {name} gauge")
        for labels, val in sorted(max_families[name], key=lambda e: e[0]):
            lines.append(_prom_sample(name, labels, val))
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------------ controller

AnyQueue = Any   # WorkQueue | DelayingQueue | FairWorkQueue | None


class Controller:
    """One reconciler: informers -> keyed work queue -> workers -> reconcile.

    Subclasses declare informers via :meth:`add_informer` (usually in
    ``__init__``; also valid at runtime — e.g. tenant registration), override
    :meth:`reconcile` (and optionally :meth:`scan`, :meth:`on_start`,
    :meth:`on_stop`), and pick a queue flavour:

    - ``WorkQueue``      — dedup FIFO;
    - ``DelayingQueue``  — dedup FIFO + delayed (rate-limited) retries;
    - ``FairWorkQueue``  — per-tenant sub-queues + WRR dispatch; items are
      ``(tenant, key)`` tuples and retries re-enter the tenant sub-queue.

    Error policy: exceptions from ``reconcile`` matching ``drop_on`` are
    forgotten; those matching ``retry_on`` are requeued with per-key
    exponential backoff (until ``max_retries``); anything else is counted as
    ``reconcile_errors`` and dropped. Workers never die on reconcile errors.
    """

    def __init__(self, name: str, *, queue: AnyQueue = None, workers: int = 1,
                 scan_interval: float = 0.0, batch_size: int = 1,
                 retry_on: Tuple[Type[BaseException], ...] = (Exception,),
                 drop_on: Tuple[Type[BaseException], ...] = (),
                 max_retries: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.name = name
        self.queue = queue
        self.workers = workers
        self.scan_interval = scan_interval
        self.batch_size = max(1, batch_size)
        self.retry_on = retry_on
        self.drop_on = drop_on
        self.max_retries = max_retries
        self.metrics = metrics or MetricsRegistry()
        self.limiter = RateLimiter()
        self.executor: Optional[CooperativeExecutor] = None
        self._informers: List[Informer] = []
        self._threads: List[threading.Thread] = []
        self._tasks: List[Task] = []
        self._stop = threading.Event()
        self._running = False
        self._scan_failing = False
        self._lifecycle_lock = threading.Lock()

    # -- declaration -------------------------------------------------------

    def add_informer(self, api: APIServer, kind: str,
                     handler: Optional[Callable[[str, Any], None]] = None,
                     name: str = "", namespace: Optional[str] = None
                     ) -> Informer:
        """Declare (and, if already running, start + sync) an informer."""
        inf = Informer(api, kind, namespace=namespace,
                       name=name or f"{self.name}/{kind}")
        if handler is not None:
            inf.add_handler(handler)
        with self._lifecycle_lock:
            self._informers.append(inf)
            running = self._running
        if running:
            inf.start(executor=self.executor)
            self._sync_unless_pooled(inf)
        return inf

    def remove_informer(self, inf: Informer) -> None:
        with self._lifecycle_lock:
            if inf in self._informers:
                self._informers.remove(inf)
        inf.stop()

    def detach_informer(self, inf: Informer) -> None:
        """Release an informer from this controller WITHOUT stopping it
        (live shard migration: the reflector keeps streaming throughout)."""
        with self._lifecycle_lock:
            if inf in self._informers:
                self._informers.remove(inf)

    def attach_informer(self, inf: Informer) -> None:
        """Adopt a (possibly already-running) informer into this controller's
        lifecycle; started here if the controller runs and it isn't yet."""
        with self._lifecycle_lock:
            self._informers.append(inf)
            running = self._running
        if running and not inf.alive:
            inf.start(executor=self.executor)
            self._sync_unless_pooled(inf)

    def _sync_unless_pooled(self, inf: Informer) -> None:
        """Block until the informer cache syncs — unless we're ON a pool
        thread, where blocking could starve the very pump task we're
        waiting for (self-deadlock at pool_size=1, a parked thread per
        registration otherwise). Reconcilers tolerate a not-yet-synced
        cache: missing informer state retries (``RetryLater``) and the
        initial replay re-delivers every key once the snapshot lands."""
        ex = self.executor
        if ex is not None and ex.in_pool_thread():
            return
        inf.wait_for_cache_sync()

    # -- overridables ------------------------------------------------------

    def reconcile(self, key: Hashable) -> None:
        raise NotImplementedError

    def reconcile_batch(self, keys: List[Hashable]) -> None:
        """Process a same-tenant batch (fair-queue coalescing); default is
        item-at-a-time with independent retry accounting."""
        for key in keys:
            self._reconcile_one(key)

    def scan(self) -> int:
        """Periodic remediation pass; returns the number of items touched."""
        return 0

    def on_start(self) -> None:
        """Hook run after informer cache sync, before workers start."""

    def on_stop(self) -> None:
        """Hook run during stop, before worker threads are joined."""

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        with self._lifecycle_lock:
            if self._running:
                return
            self._running = True
            self._stop = threading.Event()   # fresh event: restart works
            self._scan_failing = False
            informers = list(self._informers)
            ex = self.executor
        if ex is not None:
            ex.start()   # idempotent: first controller up brings the pool up
        for inf in informers:
            inf.start(executor=ex)
        for inf in informers:
            inf.wait_for_cache_sync()
        self.on_start()
        # aggregate informer accounting (cache memory budget, evict/resync,
        # relist-vs-resume) — one gauge set per controller, not per informer,
        # so a 1k-tenant fleet doesn't register 25k gauges
        def _inf_sum(attr_of: Callable[[Informer], float]) -> Callable[[], float]:
            return lambda: sum(attr_of(i) for i in tuple(self._informers))
        self.metrics.register_gauge(
            "informer_cache_nbytes",
            _inf_sum(lambda i: i.cache.nbytes_estimate()), controller=self.name)
        self.metrics.register_gauge(
            "informer_cache_evictions",
            _inf_sum(lambda i: i.cache.evict_count), controller=self.name)
        self.metrics.register_gauge(
            "informer_cache_resyncs",
            _inf_sum(lambda i: i.cache.resync_count), controller=self.name)
        self.metrics.register_gauge(
            "informer_relists",
            _inf_sum(lambda i: i.relist_count), controller=self.name)
        self.metrics.register_gauge(
            "informer_resumes",
            _inf_sum(lambda i: i.resume_count), controller=self.name)
        if self.queue is not None:
            reopen = getattr(self.queue, "reopen", None)
            if reopen is not None:
                reopen()
            self.metrics.register_gauge(
                "queue_depth", lambda: len(self.queue), controller=self.name)
            if ex is not None:
                use_executor = getattr(self.queue, "use_executor", None)
                if use_executor is not None:
                    use_executor(ex)     # delayed retries -> shared timer wheel
                for i in range(self.workers):
                    # defer + subscribe-then-wake: no add is ever missed
                    t = ex.spawn(self._worker_quantum,
                                 name=f"{self.name}-worker-{i}", defer=True)
                    self._tasks.append(t)
                    self.queue.subscribe(t.wake)
                    t.wake()
            else:
                for i in range(self.workers):
                    t = threading.Thread(target=self._worker,
                                         name=f"{self.name}-worker-{i}",
                                         daemon=True)
                    t.start()
                    self._threads.append(t)
        if self.scan_interval > 0:
            if ex is not None:
                self._tasks.append(
                    ex.spawn(self._scan_quantum, name=f"{self.name}-scan",
                             delay=self.scan_interval))
            else:
                t = threading.Thread(target=self._scan_loop,
                                     name=f"{self.name}-scan", daemon=True)
                t.start()
                self._threads.append(t)

    def stop(self) -> None:
        with self._lifecycle_lock:
            if not self._running:
                return
            self._running = False
            informers = list(self._informers)
            self._stop.set()   # under the lock: a racing start() swaps the
            #                    event first or sees _running and bails
            tasks = list(self._tasks)
        if self.queue is not None:
            self.queue.shutdown()
            for t in tasks:
                self.queue.unsubscribe(t.wake)
        for inf in informers:
            inf.stop()
        self.on_stop()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()
        for t in tasks:
            t.cancel()       # idle/ready die now; a running quantum finishes
        for t in tasks:
            t.join(timeout=5.0)
        with self._lifecycle_lock:
            self._tasks.clear()

    @property
    def running(self) -> bool:
        with self._lifecycle_lock:
            return self._running

    def healthy(self) -> bool:
        """Running, no worker/scan thread or cooperative task has died, and
        the last periodic scan (if any) succeeded."""
        with self._lifecycle_lock:
            if not self._running:
                return False
            if self._scan_failing:
                return False
            if self._tasks:
                ex = self.executor
                if ex is None or not ex.running:
                    return False
                return all(t.alive for t in self._tasks)
            return all(t.is_alive() for t in self._threads)

    # -- worker machinery --------------------------------------------------

    def _worker(self) -> None:
        q = self.queue
        fair = isinstance(q, FairWorkQueue)
        while not self._stop.is_set():
            if fair and self.batch_size > 1:
                items = q.get_batch(self.batch_size, timeout=0.2)
                if not items:
                    continue
                self.metrics.observe("batch_size", len(items),
                                     controller=self.name)
                self.reconcile_batch(items)
            else:
                item = q.get(timeout=0.2)
                if item is None:
                    continue
                self._reconcile_one(item)

    # items per cooperative quantum (amortizes dispatch without hogging the
    # pool; batched fair-queue dispatch already coalesces, so one per quantum)
    _QUANTUM_ITEMS = 8

    def _worker_quantum(self) -> Any:
        """One cooperative worker quantum: drain a bounded number of items,
        then yield (AGAIN) or park on the queue's waker (WAIT)."""
        if self._stop.is_set():
            return Task.DONE
        q = self.queue
        if isinstance(q, FairWorkQueue) and self.batch_size > 1:
            items = q.get_batch(self.batch_size, timeout=0)
            if not items:
                return Task.WAIT
            self.metrics.observe("batch_size", len(items),
                                 controller=self.name)
            self.reconcile_batch(items)
            return Task.AGAIN
        for _ in range(self._QUANTUM_ITEMS):
            item = q.get(timeout=0)
            if item is None:
                return Task.WAIT
            self._reconcile_one(item)
            if self._stop.is_set():
                return Task.DONE
        return Task.AGAIN

    def _reconcile_one(self, item: Hashable) -> None:
        t0 = time.monotonic()
        m = self.metrics
        try:
            self.reconcile(item)
            self.limiter.forget(item)
            m.inc("reconcile_total", controller=self.name)
        except BaseException as e:
            if isinstance(e, self.drop_on):
                self.limiter.forget(item)
                m.inc("reconcile_dropped", controller=self.name)
            elif isinstance(e, self.retry_on):
                self._requeue(item)
            else:
                m.inc("reconcile_errors", controller=self.name)
        finally:
            m.observe("reconcile_seconds", time.monotonic() - t0,
                      controller=self.name)
            self.queue.done(item)

    def _retry_queue(self, item: Hashable) -> AnyQueue:
        """Queue a retried item re-enters — overridable so sharded
        controllers can route it to the item's CURRENT owner (a tenant may
        have migrated shards while the item was in flight)."""
        return self.queue

    def _requeue(self, item: Hashable) -> None:
        delay = self.limiter.when(item)
        if self.max_retries is not None and \
                self.limiter.retries(item) > self.max_retries:
            self.limiter.forget(item)
            self.metrics.inc("reconcile_exhausted", controller=self.name)
            return
        self.metrics.inc("reconcile_retries", controller=self.name)
        q = self._retry_queue(item)
        ex = self.executor
        if isinstance(q, FairWorkQueue):
            if ex is not None and delay > 0:
                # honour the backoff on the shared timer wheel: an immediate
                # re-add would hot-spin RetryLater conditions (add -> wake ->
                # raise) and starve the task that clears them. The owning
                # queue is re-resolved AT FIRE TIME — a migration during the
                # backoff would otherwise strand the key on a drained queue.
                ex.call_later(delay, lambda: self._readd_fair(item),
                              name=f"{self.name}-retry")
            else:
                q.add(*item)            # re-enters the tenant sub-queue
        elif isinstance(q, DelayingQueue):
            q.add_after(item, delay)
        elif ex is not None and delay > 0:
            # plain queue on the executor: same timer-wheel backoff
            ex.call_later(delay, lambda: q.add(item),
                          name=f"{self.name}-retry")
        else:
            q.add(item)

    def _readd_fair(self, item: Hashable) -> None:
        """Re-add a retried fair-queue item to its CURRENT owning queue,
        re-checking after the add (mirrors the tenant event handlers): if a
        migration raced us, the destination dedups the double add."""
        while True:
            q = self._retry_queue(item)
            q.add(*item)
            if self._retry_queue(item) is q:
                return

    # -- periodic scan -----------------------------------------------------

    def _scan_loop(self) -> None:
        while not self._stop.wait(self.scan_interval):
            self.scan_once()

    def _scan_quantum(self) -> Any:
        """Cooperative periodic scan: one pass, then re-arm the timer wheel.
        A failing scan keeps retrying (unlike the thread fallback, whose
        scan thread dies) but flags the controller unhealthy until a pass
        succeeds, so both modes surface a broken scan in ``healthy()``."""
        if self._stop.is_set():
            return Task.DONE
        try:
            self.scan_once()
            with self._lifecycle_lock:   # _scan_failing is lock-guarded
                self._scan_failing = False
        except Exception:
            with self._lifecycle_lock:
                self._scan_failing = True
            self.metrics.inc("scan_errors", controller=self.name)
        return self.scan_interval

    def scan_once(self) -> int:
        t0 = time.monotonic()
        n = self.scan()
        dur = time.monotonic() - t0
        m = self.metrics
        m.inc("scan_runs", controller=self.name)
        m.inc("scan_items", float(n), controller=self.name)
        m.observe("scan_seconds", dur, controller=self.name)
        return n


# --------------------------------------------------------------------- manager

class ControllerManager:
    """Owns controller lifecycle, the shared metrics registry, and (when
    given one) the shared cooperative executor.

    Controllers start in registration order and stop in reverse, so wiring
    the cluster is just ``add()`` calls in dependency order. Adding to a
    started manager starts the controller immediately. An ``executor`` is
    adopted by every added controller that doesn't already have one, started
    before the first controller, shut down after the last, and exported as
    gauges (pool size, ready-task backlog, timer-wheel depth) on the shared
    registry.
    """

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 executor: Optional[CooperativeExecutor] = None):
        self.metrics = metrics or MetricsRegistry()
        self.executor = executor
        if executor is not None:
            self._register_executor_gauges()
        self._controllers: List[Controller] = []
        self._lock = threading.Lock()
        self._started = False

    def _register_executor_gauges(self) -> None:
        ex = self.executor
        m = self.metrics
        m.register_gauge("executor_pool_size", lambda: ex.pool_size)
        m.register_gauge("executor_threads", ex.thread_count)
        m.register_gauge("executor_ready_backlog", ex.ready_backlog)
        m.register_gauge("executor_timer_depth", ex.timer_depth)
        m.register_gauge("executor_tasks", ex.task_count)
        m.register_gauge("executor_quanta_total", lambda: ex.quanta_total)
        m.register_gauge("executor_quanta_seconds_total",
                         lambda: ex.quanta_seconds)
        m.register_gauge("executor_task_errors", lambda: ex.task_errors)
        m.register_gauge("executor_resizes_total", lambda: ex.resizes)

    def add(self, *controllers: Controller) -> None:
        with self._lock:
            started = self._started
            for c in controllers:
                c.metrics = self.metrics
                if c.executor is None:
                    c.executor = self.executor
                self._controllers.append(c)
        if started:
            for c in controllers:
                c.start()

    def remove(self, *controllers: Controller) -> None:
        """Drop controllers from managed lifecycle/health (the caller stops
        them — e.g. ``Syncer.resize_shards`` retiring a drained shard)."""
        with self._lock:
            for c in controllers:
                if c in self._controllers:
                    self._controllers.remove(c)

    def controller(self, name: str) -> Optional[Controller]:
        with self._lock:
            for c in self._controllers:
                if c.name == name:
                    return c
        return None

    def start(self) -> None:
        with self._lock:
            self._started = True
            controllers = list(self._controllers)
        if self.executor is not None:
            self.executor.start()
        for c in controllers:
            c.start()

    def stop(self) -> None:
        with self._lock:
            self._started = False
            controllers = list(self._controllers)
        for c in reversed(controllers):
            c.stop()
        if self.executor is not None:
            self.executor.shutdown()

    def healthy(self) -> Dict[str, bool]:
        with self._lock:
            controllers = list(self._controllers)
        return {c.name: c.healthy() for c in controllers}

    def __enter__(self) -> "ControllerManager":
        self.start()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()
